"""repro.stream: streaming-vs-offline bit-equivalence (float and LUT
paths), frontend chunking invariance, ring-buffer wraparound / restart
exactness, slot-refill warm-up, and detector hysteresis edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.configs import registry
from repro.data import pipeline
from repro.models import kwt
from repro.stream import detector as det
from repro.stream import engine
from repro.stream import features
from repro.stream import ring

KEY = jax.random.PRNGKey(0)
CFG = registry.get("kwt-tiny").config
FCFG = features.FrontendConfig()
HOP = FCFG.hop_len
T = CFG.input_dim[1]


def _audio(batch, hops, seed=1, scale=0.1):
    return scale * jax.random.normal(jax.random.PRNGKey(seed),
                                     (batch, hops * HOP))


def _run_stream(params, cfg, audio, chunk_hops=1):
    """Feed the whole stream through jitted stream_step; final state+logits."""
    state = engine.init_stream_state(cfg, FCFG, audio.shape[0])
    step = jax.jit(lambda p, s, c: engine.stream_step(p, s, c, cfg, FCFG))
    k = chunk_hops * HOP
    logits = None
    for i in range(0, audio.shape[1], k):
        state, logits = step(params, state, audio[:, i:i + k])
    return state, logits


# ---------------------------------------------------------------------------
# frontend
# ---------------------------------------------------------------------------

def test_dct_matrix_orthonormal():
    d = features.dct_matrix(FCFG.n_mels, FCFG.n_mels)
    np.testing.assert_allclose(np.asarray(d.T @ d), np.eye(FCFG.n_mels),
                               atol=1e-5)


def test_mel_filterbank_covers_band():
    fb = features.mel_filterbank(FCFG)
    assert fb.shape == (FCFG.n_fft // 2 + 1, FCFG.n_mels)
    # every filter has mass, and interior bins are covered by some filter
    assert (fb.sum(axis=0) > 0).all()


@pytest.mark.parametrize("chunk_hops", [1, 5])
def test_frontend_streaming_matches_offline_bitwise(chunk_hops):
    hops = 20
    audio = _audio(2, hops, seed=3)
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(audio)
    state = features.frontend_init(FCFG, 2)
    push = jax.jit(lambda s, c: features.frontend_push(s, c, FCFG))
    outs = []
    for i in range(0, hops, chunk_hops):
        state, fr = push(state, audio[:, i * HOP:(i + chunk_hops) * HOP])
        outs.append(fr)
    stream = jnp.swapaxes(jnp.concatenate(outs, 1), 1, 2)
    assert bool(jnp.array_equal(stream, off))


def test_frontend_chunking_invariance_bitwise():
    hops = 12
    audio = _audio(1, hops, seed=4)
    frames = {}
    for k in (2, 4):
        state = features.frontend_init(FCFG, 1)
        push = jax.jit(lambda s, c: features.frontend_push(s, c, FCFG))
        out = []
        for i in range(0, hops, k):
            state, fr = push(state, audio[:, i * HOP:(i + k) * HOP])
            out.append(fr)
        frames[k] = jnp.concatenate(out, 1)
    assert bool(jnp.array_equal(frames[2], frames[4]))


# ---------------------------------------------------------------------------
# engine: streaming output bit-identical to offline kwt.forward
# ---------------------------------------------------------------------------

def _mode_setup(mode):
    """Backend name -> (prepared params, pinned exec cfg) via the runtime
    Engine — the single source of execution policy."""
    params = kwt.init_params(CFG, KEY)
    eng = runtime.compile_model(CFG, params, backend=mode)
    return eng.params, eng.exec_cfg


@pytest.mark.parametrize("mode,chunk_hops", [
    ("float", 1), ("float", 3), ("lut_float", 1),
    ("lut", 1), ("lut", 3)])
def test_stream_bit_identical_to_offline(mode, chunk_hops):
    """The acceptance criterion: streaming logits == offline
    jax.jit(kwt.forward) on the same audio window, bit for bit, in the
    float and quantised LUT paths, at any hop chunking."""
    hops = T + 7 - (T + 7) % chunk_hops           # whole chunks, > window
    params, cfg = _mode_setup(mode)
    audio = _audio(2, hops, seed=5)
    state, logits = _run_stream(params, cfg, audio, chunk_hops)
    assert bool(engine.warm(state).all())
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(audio)[..., hops - T:]
    ref = jax.jit(lambda p, w: kwt.forward(p, w, cfg))(params, off)
    assert bool(jnp.array_equal(logits, ref)), \
        f"streaming != offline in mode={mode} (max diff " \
        f"{float(jnp.max(jnp.abs(logits - ref)))})"


def test_stream_window_matches_offline_features():
    hops = T + 5
    params, cfg = _mode_setup("float")
    audio = _audio(2, hops, seed=6)
    state, _ = _run_stream(params, cfg, audio)
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(audio)[..., hops - T:]
    assert bool(jnp.array_equal(engine.window_mfcc(state), off))


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_ring_wraparound_keeps_last_window():
    length, feat = 5, (3,)
    frames = jax.random.normal(KEY, (2, 17, 3))
    st = ring.ring_init(2, length, feat)
    for i in range(0, 15, 3):                     # k=3 pushes, wraps 3x
        st = ring.ring_push(st, frames[:, i:i + 3])
    assert bool(jnp.array_equal(ring.ring_window(st), frames[:, 10:15]))
    st = ring.ring_push(st, frames[:, 15:17])     # partial wrap (k=2)
    assert bool(jnp.array_equal(ring.ring_window(st), frames[:, 12:17]))
    assert int(st["pos"]) == 17 % length
    assert bool(ring.ring_warm(st).all())


def test_ring_warmup_gating():
    st = ring.ring_init(2, 4, ())
    assert not bool(ring.ring_warm(st).any())
    for i in range(3):
        st = ring.ring_push(st, jnp.ones((2, 1)))
        assert not bool(ring.ring_warm(st).any())
    st = ring.ring_push(st, jnp.ones((2, 1)))
    assert bool(ring.ring_warm(st).all())


def test_stream_state_restart_exactness():
    """Round-tripping the state pytree through host numpy (the checkpoint
    path) resumes the stream bit-exactly — state lives entirely in the
    pytree, not in Python objects."""
    params, cfg = _mode_setup("float")
    audio = _audio(1, 2 * T, seed=7)
    half = T * HOP
    state, _ = _run_stream(params, cfg, audio[:, :half])
    # "checkpoint": device -> host numpy -> fresh device arrays
    saved = jax.tree.map(np.asarray, jax.device_get(state))
    restored = jax.tree.map(jnp.asarray, saved)
    step = jax.jit(lambda p, s, c: engine.stream_step(p, s, c, cfg, FCFG))
    out_a, out_b = [], []
    sa, sb = state, restored
    for i in range(half, 2 * half, HOP):
        sa, la = step(params, sa, audio[:, i:i + HOP])
        sb, lb = step(params, sb, audio[:, i:i + HOP])
        out_a.append(la)
        out_b.append(lb)
    assert bool(jnp.array_equal(jnp.stack(out_a), jnp.stack(out_b)))


def test_reset_lane_rewarms_and_matches_fresh_stream():
    """Server slot refill: resetting one lane restarts its warm-up and its
    post-warm logits equal a stream that never shared the batch."""
    params, cfg = _mode_setup("float")
    a01 = _audio(2, T + 3, seed=8)                # both lanes run a while
    state, _ = _run_stream(params, cfg, a01)
    state = engine.reset_lane(state, 0)
    assert not bool(engine.warm(state)[0])
    assert bool(engine.warm(state)[1])
    # refill lane 0 with new audio; lane 1 keeps streaming different audio
    fresh = _audio(2, T, seed=9)
    cont = jnp.concatenate([fresh[:1], _audio(1, T, seed=10)], axis=0)
    step = jax.jit(lambda p, s, c: engine.stream_step(p, s, c, cfg, FCFG))
    logits = None
    for i in range(0, T * HOP, HOP):
        state, logits = step(params, state, cont[:, i:i + HOP])
    assert bool(engine.warm(state).all())
    # oracle: both lanes' windows through the offline forward, same batch
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(cont)
    ref = jax.jit(lambda p, w: kwt.forward(p, w, cfg))(params, off)
    assert bool(jnp.array_equal(logits[0], ref[0]))


# ---------------------------------------------------------------------------
# detector hysteresis / refractory
# ---------------------------------------------------------------------------

DCFG = det.DetectorConfig(keyword_class=1, smooth_hops=1,
                          on_threshold=0.75, off_threshold=0.5,
                          refractory_hops=4)


def _drive(seq, dcfg=DCFG, warm=True):
    """Feed a scalar keyword-posterior sequence; return fire pattern."""
    st = det.detector_init(dcfg, 1)
    fires = []
    for p in seq:
        probs = jnp.asarray([[1.0 - p, p]], jnp.float32)
        st, ev = det.detector_step(st, probs, dcfg,
                                   warm=jnp.asarray([warm]))
        fires.append(bool(ev["fired"][0]))
    return fires


def test_detector_fires_once_per_excursion():
    fires = _drive([0.1, 0.9, 0.9, 0.9, 0.9, 0.1])
    assert fires == [False, True, False, False, False, False]


def test_detector_no_refire_without_release():
    # dips to between off(0.5) and on(0.75): hysteresis holds the latch
    fires = _drive([0.9, 0.6, 0.6, 0.9, 0.9])
    assert fires == [True, False, False, False, False]


def test_detector_refractory_blocks_fast_refire():
    # released (below off) but still inside the 4-hop refractory window
    fires = _drive([0.9, 0.1, 0.9, 0.9, 0.9, 0.9])
    assert fires[0] is True
    assert fires[1:4] == [False, False, False]    # cooldown 4 hops
    assert fires[4] is True                       # expires -> re-fires
    assert fires[5] is False


def test_detector_release_then_refire_after_refractory():
    fires = _drive([0.9, 0.1, 0.1, 0.1, 0.1, 0.9, 0.1, 0.9])
    assert fires == [True, False, False, False, False, True, False, False]


def test_detector_warm_gating():
    fires = _drive([0.9, 0.9], warm=False)
    assert fires == [False, False]


def test_detector_smoothing_suppresses_single_hop_spike():
    dcfg = det.DetectorConfig(smooth_hops=4, on_threshold=0.75,
                              off_threshold=0.5, refractory_hops=2)
    fires = _drive([0.1, 0.95, 0.1, 0.1, 0.1], dcfg)
    assert not any(fires)                         # 1-hop spike averaged away
    fires = _drive([0.9] * 6, dcfg)
    assert sum(fires) == 1                        # sustained keyword fires


def test_detector_reset_lane_rearms():
    st = det.detector_init(DCFG, 2)
    hot = jnp.asarray([[0.1, 0.9]] * 2, jnp.float32)
    st, ev = det.detector_step(st, hot, DCFG)
    assert bool(ev["fired"].all())
    st = det.detector_reset_lane(st, 0)
    st, ev = det.detector_step(st, hot, DCFG)
    assert bool(ev["fired"][0])                   # lane 0 re-armed
    assert not bool(ev["fired"][1])               # lane 1 still latched


# ---------------------------------------------------------------------------
# data: audio surrogates
# ---------------------------------------------------------------------------

def test_keyword_audio_batch_deterministic_and_labelled():
    b1 = pipeline.keyword_audio_batch(0, 3, batch=4, n_samples=T * HOP)
    b2 = pipeline.keyword_audio_batch(0, 3, batch=4, n_samples=T * HOP)
    assert bool(jnp.array_equal(b1["audio"], b2["audio"]))
    assert b1["audio"].shape == (4, T * HOP)
    # keyword clips carry more energy than pure noise
    e = jnp.mean(jnp.square(b1["audio"]), axis=1)
    if bool((b1["labels"] == 1).any()) and bool((b1["labels"] == 0).any()):
        assert float(jnp.min(jnp.where(b1["labels"] == 1, e, jnp.inf))) > \
            float(jnp.max(jnp.where(b1["labels"] == 0, e, -jnp.inf)))


def test_keyword_event_stream_ground_truth():
    audio, events = pipeline.keyword_event_stream(0, 1, n_hops=200,
                                                  hop_len=HOP)
    assert audio.shape == (200 * HOP,)
    assert events, "expected at least one keyword event in 2s"
    for s, e in events:
        assert 0 <= s < e <= 200


# ---------------------------------------------------------------------------
# review hardening: ring overrun, lean server state, warm-up contamination
# ---------------------------------------------------------------------------

def test_ring_push_wider_than_ring_rejected():
    st = ring.ring_init(1, 4, ())
    with pytest.raises(AssertionError, match="overruns"):
        ring.ring_push(st, jnp.ones((1, 5)))


def test_keep_features_false_still_bit_identical():
    """The lean server state (no raw-MFCC ring) produces the same logits."""
    hops = T + 4
    params, cfg = _mode_setup("float")
    audio = _audio(2, hops, seed=11)
    state = engine.init_stream_state(cfg, FCFG, 2, keep_features=False)
    assert "feat" not in state
    step = jax.jit(lambda p, s, c: engine.stream_step(p, s, c, cfg, FCFG))
    for i in range(0, hops * HOP, HOP):
        state, logits = step(params, state, audio[:, i:i + HOP])
    state = engine.reset_lane(state, 0)           # lean reset path works too
    assert not bool(engine.warm(state)[0])
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(audio)[..., hops - T:]
    ref = jax.jit(lambda p, w: kwt.forward(p, w, cfg))(params, off)
    assert bool(jnp.array_equal(logits, ref))


def test_detector_warmup_history_cannot_fire_at_warm_boundary():
    """Posteriors collected while the lane was NOT warm (zero-padded
    windows) must age out of the smoothing history before a fire: a lane
    that scored keyword-like during warm-up may only fire after
    smooth_hops consecutive warm hops."""
    dcfg = det.DetectorConfig(smooth_hops=3, on_threshold=0.75,
                              off_threshold=0.5, refractory_hops=2)
    st = det.detector_init(dcfg, 1)
    hot = jnp.asarray([[0.1, 0.9]], jnp.float32)
    for _ in range(5):                            # padded window looks hot
        st, ev = det.detector_step(st, hot, dcfg, warm=jnp.asarray([False]))
        assert not bool(ev["fired"][0])
    for i in range(3):                            # warm hops 1..3
        st, ev = det.detector_step(st, hot, dcfg, warm=jnp.asarray([True]))
        assert bool(ev["fired"][0]) == (i == 2)   # fires only at hop 3
