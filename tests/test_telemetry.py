"""repro.telemetry: tracing, metrics, quantisation-health taps.

The PR-7 contracts:

* taps-on logits are bit-identical to the untapped plan on every backend
  (the aux comes from a separate jitted program; the serving executable
  never changes);
* histogram quantiles are correct, including after the ring reservoir
  wraps;
* emitted traces validate against the Chrome trace-event schema and the
  Prometheus text exposition validates as Prometheus;
* the telemetry-disabled fast path adds no per-call allocation in
  ``Engine.forward`` (one shared no-op span, one global read).
"""

import json
import tracemalloc

import jax
import numpy as np
import pytest

from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.models import kwt
from repro.telemetry import check as tcheck
from repro.telemetry import taps

KEY = jax.random.PRNGKey(0)
CFG = registry.get("kwt-tiny").config


@pytest.fixture(scope="module")
def params():
    return kwt.init_params(CFG, KEY)


@pytest.fixture(scope="module")
def mfcc():
    return 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                   (2, *CFG.input_dim))


@pytest.fixture(autouse=True)
def _tracing_off():
    yield
    telemetry.disable()


# ---------------------------------------------------------------------------
# taps: bit-identity + health stats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["float", "lut", "pallas"])
def test_taps_logits_bit_identical(params, mfcc, backend):
    eng = runtime.compile_model(CFG, params, backend=backend)
    engt = runtime.compile_model(CFG, params, backend=backend, taps=True)
    base = np.asarray(eng.forward(mfcc))
    logits, aux = engt.forward(mfcc)
    assert np.array_equal(np.asarray(logits), base)
    # per-layer aux is present, scoped, and finite
    assert "block0/softmax" in aux
    assert "lut_oob_frac" in aux["block0/softmax"]
    assert "embed" in aux and "logits" in aux
    for site, stats in aux.items():
        for stat, v in stats.items():
            assert np.isfinite(float(v)), f"{site}/{stat} not finite"


def test_taps_off_by_default_and_no_aux(params, mfcc):
    eng = runtime.compile_model(CFG, params, backend="lut")
    assert eng.taps is False
    out = eng.forward(mfcc)
    assert not isinstance(out, tuple)


def test_taps_report_saturation_when_activations_hot(params):
    """Scores far beyond the eq-9 grid edge must read as saturated.
    Uses the non-executing resident plan: the int-exec flavour's input
    quantiser clips hot activations INSIDE the linears, so its embed
    output is already bounded — the tap's pre-clip view needs the float
    activation path."""
    hot = 300.0 * jax.random.normal(jax.random.PRNGKey(2),
                                    (2, *CFG.input_dim))
    engt = runtime.compile_model(CFG, params, backend="lut", taps=True,
                                 integer_exec=False)
    _, aux = engt.forward(hot)
    assert float(aux["embed"]["int8_sat_frac"]) > 0.5
    assert float(aux["embed"]["q24_headroom_bits"]) < 0


def test_tap_calls_are_noops_without_collector():
    """Model code calls taps unconditionally; inactive they must emit
    nothing and leave no trace in the jaxpr."""
    assert not taps.active()
    taps.tap_gelu(np.zeros((4,)))
    taps.tap_softmax(np.zeros((2, 4)))
    with taps.collecting() as col:
        assert taps.active()
        taps.tap_gelu(np.zeros((4,)))
    assert not taps.active()
    assert len(col) == 1 and col[0][0] == "gelu"


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------

def test_histogram_quantiles_match_numpy():
    h = telemetry.Histogram("lat", unit="ms", capacity=2048)
    vals = np.random.RandomState(0).lognormal(0, 1, 1000)
    for v in vals:
        h.observe(v)
    for q in (0.5, 0.95, 0.99):
        assert h.quantile(q) == pytest.approx(
            np.percentile(vals, 100 * q), rel=1e-12)
    s = h.summary()
    assert s["n"] == 1000
    assert s["p50_ms"] == pytest.approx(np.percentile(vals, 50), abs=1e-3)


def test_histogram_ring_keeps_latest_window():
    h = telemetry.Histogram("lat", capacity=10)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100                      # true count survives the ring
    assert sorted(h.values()) == [float(v) for v in range(90, 100)]
    assert h.quantile(0.5) == pytest.approx(np.percentile(range(90, 100), 50))


def test_latency_summary_is_the_shared_schema():
    s = telemetry.latency_summary([1.0, 2.0, 3.0], unit="us")
    assert set(s) == {"n", "mean_us", "p50_us", "p95_us", "p99_us"}
    assert s["n"] == 3 and s["p50_us"] == 2.0


# ---------------------------------------------------------------------------
# trace + Prometheus format validation
# ---------------------------------------------------------------------------

def test_trace_validates_against_chrome_schema(tmp_path):
    with telemetry.tracing() as tr:
        with telemetry.span("forward", {"backend": "float"}):
            with telemetry.span("unpack"):
                pass
            with telemetry.span("encode"):
                pass
        tr.instant("marker")
    path = tr.save(str(tmp_path / "trace.json"))
    n = telemetry.validate_chrome_trace(path)
    assert n == 4
    obj = json.load(open(path))
    by_name = {e["name"]: e for e in obj["traceEvents"]}
    assert by_name["unpack"]["args"]["parent"] == "forward"
    assert by_name["encode"]["ph"] == "X" and by_name["encode"]["dur"] >= 0
    assert by_name["marker"]["ph"] == "i"


def test_trace_schema_violations_rejected():
    with pytest.raises(tcheck.TelemetryFormatError):
        telemetry.validate_chrome_trace({"events": []})    # wrong key
    with pytest.raises(tcheck.TelemetryFormatError):
        telemetry.validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "ts": 0,
                              "pid": 1, "tid": 1}]})       # X without dur


def test_span_coverage_accounts_children():
    with telemetry.tracing() as tr:
        with tr.span("forward"):
            with tr.span("unpack"):
                sum(range(2000))
            with tr.span("encode"):
                sum(range(20000))
    cov = telemetry.span_coverage(tr, "forward")
    assert 0.5 < cov <= 1.0


def test_prometheus_export_validates():
    reg = telemetry.Registry()
    reg.counter("events_total", "events", {"backend": "lut"}).inc(3)
    reg.gauge("queue_depth", "depth").set(7)
    h = reg.histogram("hop_latency_ms", "latency", unit="ms")
    for v in range(50):
        h.observe(float(v))
    text = reg.to_prometheus()
    assert telemetry.validate_prometheus(text) == 7   # 1 + 1 + (3q + sum + n)
    assert 'events_total{backend="lut"} 3' in text
    with pytest.raises(tcheck.TelemetryFormatError):
        telemetry.validate_prometheus("no_type_line 1")


def test_registry_save_layout_matches_checker(tmp_path):
    reg = telemetry.Registry()
    reg.counter("c_total").inc()
    with telemetry.tracing() as tr:
        with telemetry.span("hop"):
            pass
    trace = tr.save(str(tmp_path / "t.json"))
    reg.save(str(tmp_path / "t"))
    out = tcheck.check_artifacts(trace, require_metrics=True)
    assert out["events"] == 1 and out["prom_samples"] == 1


def test_structured_log_line_is_parseable(capsys):
    line = telemetry.log("serve_done", streams=4, rtf=0.123456,
                         note="two words")
    assert line.startswith("event=serve_done ts=")
    assert "rtf=0.1235" in line and 'note="two words"' in line
    assert capsys.readouterr().out.strip() == line


# ---------------------------------------------------------------------------
# disabled fast path: no per-call allocation
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_singleton():
    telemetry.disable()
    s = telemetry.span("anything")
    assert s is telemetry.NOOP_SPAN
    assert s is telemetry.span("something_else", {"k": 1})


def test_disabled_span_allocates_nothing():
    telemetry.disable()
    for _ in range(4):                      # warm any lazy caches
        with telemetry.span("warm"):
            pass
    tracemalloc.start()
    snap1 = tracemalloc.take_snapshot()
    for _ in range(200):
        with telemetry.span("hot"):
            pass
    snap2 = tracemalloc.take_snapshot()
    tracemalloc.stop()
    grew = [st for st in snap2.compare_to(snap1, "lineno")
            if st.size_diff > 0 and "repro/telemetry" in str(st.traceback)]
    assert not grew, f"disabled span path allocated: {grew}"


def test_engine_forward_disabled_path_unchanged(params, mfcc):
    """With tracing off and taps unplanned, forward must be the plain
    one-jit call — same executable, same result object type."""
    telemetry.disable()
    eng = runtime.compile_model(CFG, params, backend="float")
    base = np.asarray(eng.forward(mfcc))
    with telemetry.tracing() as tr:
        traced = np.asarray(eng.forward(mfcc))
    assert np.array_equal(base, traced)     # tracing never changes numerics
    names = {e["name"] for e in tr.events}
    # float params = no unpack program = no unpack span (the stage does
    # not exist for this plan, so nothing is attributed to it)
    assert names == {"forward", "encode"}
    after = np.asarray(eng.forward(mfcc))   # disabled again -> no new events
    assert np.array_equal(base, after)
    assert len(tr.events) == 2
