"""Training infrastructure: optimizer, checkpointing, data determinism,
fault-tolerant train loop (crash + resume), quantised serving path."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager
from repro.configs import registry
from repro.data import pipeline
from repro.optim import adamw


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quadratic_setup(int8):
    hp = adamw.HParams(lr=0.1, weight_decay=0.0, warmup_steps=0,
                       total_steps=100, int8_moments=int8)
    params = {"blocks": {"w": jnp.ones((4, 8, 8))},
              "embed": jnp.ones((8, 8))}
    return hp, params


@pytest.mark.parametrize("int8", [False, True])
def test_adamw_descends(int8):
    hp, params = _quadratic_setup(int8)
    state = adamw.init(params, hp)

    def loss(p):
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(30):
        grads = jax.grad(loss)(params)
        params, state, m = adamw.update(grads, state, params, hp)
    assert float(loss(params)) < 0.5 * l0
    assert float(m["lr"]) > 0


def test_int8_moments_track_f32():
    hp8, params = _quadratic_setup(True)
    hpf, _ = _quadratic_setup(False)
    s8, sf = adamw.init(params, hp8), adamw.init(params, hpf)
    p8 = pf = params

    def loss(p):
        return sum(jnp.sum(jnp.square(x - 3.0)) for x in jax.tree.leaves(p))

    for _ in range(20):
        p8, s8, _ = adamw.update(jax.grad(loss)(p8), s8, p8, hp8)
        pf, sf, _ = adamw.update(jax.grad(loss)(pf), sf, pf, hpf)
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(p8), jax.tree.leaves(pf)))
    assert d < 0.05      # int8 moments stay close to the f32 trajectory


def test_schedule_warmup_and_decay():
    hp = adamw.HParams(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.schedule(jnp.asarray(5), hp)) == pytest.approx(0.5)
    assert float(adamw.schedule(jnp.asarray(10), hp)) == pytest.approx(1.0, abs=0.02)
    assert float(adamw.schedule(jnp.asarray(100), hp)) == pytest.approx(
        hp.min_lr_ratio, abs=0.02)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    manager.save(str(tmp_path), 7, tree)
    assert manager.latest_step(str(tmp_path)) == 7
    out = manager.restore(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_ignores_incomplete(tmp_path):
    tree = {"a": jnp.ones((2,))}
    manager.save(str(tmp_path), 3, tree)
    # simulate a crash mid-save: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000009.tmp-dead")
    # and a renamed dir missing the manifest sentinel
    os.makedirs(tmp_path / "step_00000005")
    assert manager.latest_step(str(tmp_path)) == 3


def test_checkpoint_async(tmp_path):
    tree = {"a": jnp.ones((100, 100))}
    t = manager.save(str(tmp_path), 1, tree, blocking=False)
    t.join()
    assert manager.latest_step(str(tmp_path)) == 1


def test_checkpoint_packed_qtensor_tree_roundtrip(tmp_path):
    """Packed QTensor trees round-trip WITHOUT upcasting: the stored
    leaves (nibble-packed uint8 / int8 bodies, int8 axis exponents) come
    back at their packed dtypes and the static exponent/bits/shape ride
    the treedef — the checkpoint is the flashable ROM image."""
    from repro.core import quant
    from repro.runtime.recipe import QuantRecipe

    w = 0.3 * jnp.asarray(np.random.RandomState(0).randn(9, 5), jnp.float32)
    tree = {"w4": quant.quantize_po2(w, 4, bits=4),
            "w8": quant.quantize_po2(w, 6, bits=8),
            "pc": QuantRecipe(per_channel=True)._quantize_leaf(w),
            "norm": jnp.ones((5,))}
    manager.save(str(tmp_path), 2, tree)
    target = jax.tree.map(jnp.zeros_like, tree)
    out = manager.restore(str(tmp_path), 2, target)
    assert out["w4"].values.dtype == jnp.uint8        # no upcast
    assert out["w4"].values.size == (9 * 5 + 1) // 2  # packed bytes on disk
    assert out["w4"].bits == 4 and out["w4"].shape == (9, 5)
    assert out["w8"].values.dtype == jnp.int8
    assert out["pc"].axis_exponents.dtype == jnp.int8
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and the restored tree dequantises identically (no float detour lost)
    np.testing.assert_array_equal(np.asarray(out["w4"].dequantize()),
                                  np.asarray(tree["w4"].dequantize()))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_skippable():
    a = pipeline.lm_batch(0, 5, global_batch=4, seq_len=16, vocab_size=100)
    b = pipeline.lm_batch(0, 5, global_batch=4, seq_len=16, vocab_size=100)
    c = pipeline.lm_batch(0, 6, global_batch=4, seq_len=16, vocab_size=100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert int(a["tokens"].max()) < 100
    # labels are next-token shifted
    kw = pipeline.keyword_batch(0, 0, batch=8)
    assert kw["mfcc"].shape == (8, 16, 26)
    assert set(np.asarray(kw["labels"]).tolist()) <= {0, 1}


# ---------------------------------------------------------------------------
# fault-tolerant train loop (crash -> resume)
# ---------------------------------------------------------------------------

def test_train_crash_and_resume(tmp_path):
    from repro.launch import train as train_mod

    args = ["--arch", "internlm2-1.8b", "--smoke", "--steps", "8",
            "--global-batch", "4", "--seq-len", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"]
    # run 1: crash at step 5 (checkpoints exist for steps 2 and 4)
    with pytest.raises(RuntimeError, match="injected failure"):
        train_mod.main(args + ["--fail-at-step", "5"])
    assert manager.latest_step(str(tmp_path)) == 4
    # run 2: resumes from step 4 and completes
    params_resumed = train_mod.main(args)
    # reference: uninterrupted run
    ref = train_mod.main(["--arch", "internlm2-1.8b", "--smoke", "--steps",
                          "8", "--global-batch", "4", "--seq-len", "16"])
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params_resumed), jax.tree.leaves(ref)))
    # deterministic data + exact state restore => identical trajectories
    assert d < 1e-5


def test_train_loss_decreases():
    from repro.launch import train as train_mod
    import io, contextlib

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        train_mod.main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "30",
                        "--global-batch", "8", "--seq-len", "32"])
    lines = [l for l in buf.getvalue().splitlines() if l.startswith("step")]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first - 0.1


# ---------------------------------------------------------------------------
# quantised serving path (the paper's technique end to end at LM scale)
# ---------------------------------------------------------------------------

def test_quantized_lm_logits_close():
    from repro.models import transformer as T

    cfg = registry.get("internlm2-1.8b").smoke
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    ref = T.forward(params, toks, cfg)
    from repro import runtime
    eng = runtime.compile_model(cfg, params, backend="lut_float")
    got = eng.forward(toks)
    # ranks should broadly agree even though values shift
    agree = jnp.mean((jnp.argmax(got, -1) == jnp.argmax(ref, -1)).astype(jnp.float32))
    assert float(agree) > 0.5
    assert bool(jnp.all(jnp.isfinite(got)))


# ---------------------------------------------------------------------------
# compressed gradient sync wired into the train step (dist.compress)
# ---------------------------------------------------------------------------

def test_train_step_with_compressed_grad_sync_tracks_exact():
    """make_train_step(sync_mesh=...) threads the error-feedback state and
    stays close to the uncompressed trajectory on a 1-device ring (where
    the only difference is the int8 round trip)."""
    from repro.configs.base import ShapeSpec
    from repro.dist import compress
    from repro.launch import steps as steps_mod

    cfg = registry.get("kwt-tiny").config
    shape = ShapeSpec("t", cfg.input_dim[1], 8, "train")
    mesh = jax.make_mesh((1,), ("data",))
    hp = adamw.HParams(lr=1e-3, warmup_steps=2, total_steps=10,
                       weight_decay=0.0)
    from repro.models import kwt
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    ref_params = params
    opt = adamw.init(params, hp)
    ref_opt = adamw.init(ref_params, hp)
    err = compress.init_error_state(params)

    plain = jax.jit(steps_mod.make_train_step(cfg, shape, hp, n_micro=1))
    synced = jax.jit(steps_mod.make_train_step(cfg, shape, hp, n_micro=1,
                                               sync_mesh=mesh,
                                               sync_per_channel=True))
    for i in range(5):
        batch = pipeline.keyword_batch(0, i, batch=8,
                                       input_dim=cfg.input_dim)
        params, opt, err, m = synced(params, opt, err, batch)
        ref_params, ref_opt, mr = plain(ref_params, ref_opt, batch)
        assert jnp.isfinite(m["loss"])
    # error state is live (quantisation residuals are being carried)
    assert any(float(jnp.max(jnp.abs(e))) > 0 for e in jax.tree.leaves(err))
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(ref_params)))
    assert d < 5e-3     # int8 wire barely perturbs the AdamW trajectory
