"""repro.cell: continuous-batching join/evict bit-identity, admission
control, hop-pipeline parity, checkpoint hot-swap, and the satellite
hardening (serve_common crash flush, detector lane recycling, checkpoint
partial-write tolerance)."""

import json
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cell as cellmod
from repro import runtime
from repro import telemetry
from repro.cell import admission as admission_mod
from repro.checkpoint import manager
from repro.configs import registry
from repro.launch import serve_common
from repro.launch import steps
from repro.models import kwt
from repro.models import transformer
from repro.stream import detector as det
from repro.stream import engine as stream_engine
from repro.stream import features

FCFG = features.FrontendConfig()
HOP = FCFG.hop_len


@pytest.fixture(scope="module")
def lm_engine():
    cfg = registry.get("internlm2-1.8b").smoke
    params = steps.model_module(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return runtime.compile_model(cfg, params, backend="float")


@pytest.fixture(scope="module")
def kwt_setup():
    cfg = registry.get("kwt-tiny").smoke
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _metrics():
    return telemetry.make_cell_metrics(telemetry.Registry())


# ---------------------------------------------------------------------------
# per-lane decode state (models.transformer)
# ---------------------------------------------------------------------------

def test_vector_index_decode_matches_scalar(lm_engine):
    """A per-lane [B] index at uniform depth must reproduce the scalar-
    index decode — the mechanism under continuous batching."""
    eng = lm_engine
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                              eng.cfg.vocab_size)
    logits, s = eng.prefill(toks, eng.init_decode_state(B, 12))
    s_vec = {"layers": s["layers"],
             "index": jnp.broadcast_to(s["index"], (B,))}
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    cur_v = cur
    for _ in range(4):
        la, s = eng.decode_step(cur, s)
        lb, s_vec = eng.decode_step(cur_v, s_vec)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=0, atol=0)
        cur = jnp.argmax(la, -1).astype(jnp.int32)
        cur_v = jnp.argmax(lb, -1).astype(jnp.int32)


def test_merge_decode_state_selects_per_lane(lm_engine):
    eng = lm_engine
    old = eng.init_decode_state(2, 8)
    new = eng.init_decode_state(2, 8)
    old["index"] = jnp.asarray([3, 5], jnp.int32)
    new["index"] = jnp.asarray([0, 0], jnp.int32)
    new["layers"] = jax.tree.map(
        lambda a: a + 1 if jnp.issubdtype(a.dtype, jnp.floating) else a,
        new["layers"])
    merged = transformer.merge_decode_state(old, new,
                                            jnp.asarray([False, True]))
    np.testing.assert_array_equal(np.asarray(merged["index"]), [3, 0])
    k = jax.tree.leaves(merged["layers"])[0]       # [n_layers, B, ...]
    assert float(jnp.sum(jnp.abs(k[:, 0].astype(jnp.float32)))) == 0.0
    assert float(jnp.sum(jnp.abs(k[:, 1].astype(jnp.float32)))) > 0.0


# ---------------------------------------------------------------------------
# LMScheduler: continuous batching
# ---------------------------------------------------------------------------

def _requests(cfg, n=5, seed=0):
    rng = np.random.RandomState(seed)
    return [(i, rng.randint(0, cfg.vocab_size, size=rng.randint(2, 12)),
             int(rng.randint(3, 10))) for i in range(n)]


def test_scheduler_order_invariant(lm_engine):
    """With a fixed prefill pad width, the schedule is invisible: any
    submission order yields bit-identical tokens per request."""
    reqs = _requests(lm_engine.cfg)

    def run(order):
        s = cellmod.LMScheduler(lm_engine, slots=2, max_len=64,
                                prefill_len=16)
        for j in order:
            rid, p, g = reqs[j]
            s.submit(rid, p, g)
        return s.run()

    a, b = run([0, 1, 2, 3, 4]), run([4, 3, 2, 1, 0])
    assert set(a) == set(b) == {0, 1, 2, 3, 4}
    for rid in a:
        assert a[rid] == b[rid]
        assert len(a[rid]) == reqs[rid][2]


def test_scheduler_preserves_residents_on_join(lm_engine):
    """THE continuous-batching property (and the launch/serve.py refill
    bug this subsystem fixes): a mid-flight join must not perturb a
    resident lane's decode — same tokens as an undisturbed run."""
    reqs = _requests(lm_engine.cfg)
    solo = cellmod.LMScheduler(lm_engine, slots=2, max_len=64,
                               prefill_len=16)
    solo.submit(0, reqs[0][1], reqs[0][2])
    want = solo.run()[0]

    s = cellmod.LMScheduler(lm_engine, slots=2, max_len=64, prefill_len=16)
    s.submit(0, reqs[0][1], reqs[0][2])
    out, n = {}, 0
    while not s.idle():
        if n == 2:                       # joiner lands mid-decode
            s.submit(1, reqs[1][1], reqs[1][2])
        for ev in s.step():
            out.setdefault(ev.rid, []).append(ev.token)
        n += 1
    assert out[0] == want
    assert len(out[1]) == reqs[1][2]


def test_scheduler_eos_evicts_early(lm_engine):
    s = cellmod.LMScheduler(lm_engine, slots=2, max_len=64, prefill_len=16)
    s.submit(0, [1, 2, 3], 40)
    evs = []
    while not s.idle():
        evs += s.step()
    # rerun with the first emitted token as EOS: must stop at one token
    eos = evs[0].token
    s2 = cellmod.LMScheduler(lm_engine, slots=2, max_len=64, prefill_len=16,
                             eos_id=eos)
    s2.submit(0, [1, 2, 3], 40)
    out = []
    while not s2.idle():
        out += s2.step()
    assert len(out) == 1 and out[0].done and out[0].reason == "eos"


def test_scheduler_metrics_ledger(lm_engine):
    met = _metrics()
    s = cellmod.LMScheduler(lm_engine, slots=2, max_len=64, prefill_len=16,
                            metrics=met)
    reqs = _requests(lm_engine.cfg, n=3)
    for rid, p, g in reqs:
        s.submit(rid, p, g)
    out = s.run()
    assert met.joins.value == 3 and met.evictions.value == 3
    assert met.tokens.value == sum(len(v) for v in out.values())
    assert met.prefill_tokens.value == sum(len(p) for _, p, _ in reqs)


def test_scheduler_rejects_recurrent_families():
    """rwkv/hybrid fold pad tokens irreversibly into recurrence state —
    they keep the drain-batch serve path."""
    fake = types.SimpleNamespace(
        exec_cfg=types.SimpleNamespace(family="rwkv"))
    with pytest.raises(AssertionError, match="dense/moe"):
        cellmod.LMScheduler(fake, slots=2, max_len=8)


def test_scheduler_rejects_oversized_request(lm_engine):
    s = cellmod.LMScheduler(lm_engine, slots=2, max_len=16)
    with pytest.raises(AssertionError):
        s.submit(0, list(range(10)), 8)          # 9 + 8 > 16


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_admission_bounded_queue():
    met = _metrics()
    a = admission_mod.AdmissionController(
        admission_mod.AdmissionConfig(max_queue=2), metrics=met)
    assert a.offer("s0").admitted and a.offer("s1").admitted
    d = a.offer("s2")
    assert not d.admitted and d.reason == "queue_full"
    assert met.admitted.value == 2 and met.rejected.value == 1
    assert a.pop() == "s0" and len(a) == 1


def test_admission_token_bucket():
    clk = _Clock()
    a = admission_mod.AdmissionController(
        admission_mod.AdmissionConfig(max_queue=100, rate=2.0, burst=2),
        clock=clk)
    assert a.offer(0).admitted and a.offer(1).admitted
    assert a.offer(2).reason == "rate"           # bucket drained
    clk.t += 0.5                                 # refills one token
    assert a.offer(3).admitted
    assert not a.offer(4).admitted


def test_admission_deadline_shed():
    clk = _Clock()
    met = _metrics()
    a = admission_mod.AdmissionController(
        admission_mod.AdmissionConfig(max_queue=10, deadline_ms=100.0),
        metrics=met, clock=clk)
    a.offer("stale")
    clk.t += 0.2                                 # 200 ms > deadline
    a.offer("fresh")
    assert a.pop() == "fresh"                    # stale one was shed
    assert met.rejected.value == 1


def test_admission_degrades_before_rejecting():
    clk = _Clock()
    met = _metrics()
    cfg = admission_mod.AdmissionConfig(max_queue=4, degrade_queue=2,
                                        degraded_chunk_hops=4,
                                        deadline_ms=1000.0)
    a = admission_mod.AdmissionController(cfg, metrics=met, clock=clk)
    a.offer(0)
    a.offer(1)
    assert a.chunk_hops() == 1                   # within bounds
    a.offer(2)                                   # queue depth 3 > 2
    assert a.chunk_hops() == 4                   # degraded, nothing shed
    assert met.degraded.value == 1 and met.rejected.value == 0
    a.offer(3)
    assert not a.offer(4).admitted               # only now: reject
    for _ in range(4):
        a.pop()
    assert a.chunk_hops() == 1                   # drained: recovers


# ---------------------------------------------------------------------------
# hop pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["float", "lut"])
def test_pipeline_split_matches_fused(kwt_setup, backend):
    """The featurise/encode split reproduces the fused stream_step logits
    bit-for-bit (the barrier seam is the split point), and the pipelined
    generator reproduces the synchronous split path."""
    cfg, params = kwt_setup
    eng = runtime.compile_model(cfg, params, backend=backend)
    pipe = cellmod.HopPipeline(eng, FCFG)
    rng = np.random.RandomState(0)
    chunks = [rng.randn(2, HOP).astype(np.float32) * 0.1 for _ in range(5)]

    s_fused = stream_engine.init_stream_state(cfg, FCFG, 2,
                                              keep_features=False)
    s_split = pipe.init_state(2)
    sync = []
    for c in chunks:
        s_fused, l_f = eng.stream_step(s_fused, jnp.asarray(c), FCFG)
        s_split, l_s = pipe.step(s_split, c)
        np.testing.assert_array_equal(np.asarray(l_f), np.asarray(l_s))
        sync.append(np.asarray(l_s))
    piped = [np.asarray(l) for _, l in pipe.run(pipe.init_state(2), chunks)]
    assert len(piped) == len(sync)
    for a, b in zip(sync, piped):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# hot-swap
# ---------------------------------------------------------------------------

def _packed(cfg, seed):
    """A packed int8 QTensor tree — the deploy artifact hot_swap loads."""
    params = kwt.init_params(cfg, jax.random.PRNGKey(seed))
    return runtime.QuantRecipe.from_config(cfg).quantize(params)


def test_hot_swap_parity_gate_and_generation(kwt_setup):
    cfg, _ = kwt_setup
    eng = runtime.compile_model(cfg, _packed(cfg, 0), backend="lut")
    assert eng.int_resident
    handle = runtime.EngineHandle(eng)
    probe = jnp.asarray(np.random.RandomState(1).randn(
        1, *cfg.input_dim).astype(np.float32))
    before = np.asarray(handle.engine.forward(probe))
    lp0 = handle.live_params()
    assert handle.live_params() is lp0           # cached per generation

    met = _metrics()
    q2 = _packed(cfg, 7)
    old = cellmod.hot_swap(handle, q2, probe, metrics=met)
    assert old is eng and handle.generation == 1
    assert met.swaps.value == 1 and met.swap_failures.value == 0
    after = np.asarray(handle.engine.forward(probe))
    assert not np.array_equal(before, after)
    # the deploy gate's own criterion, re-checked from outside: the
    # installed integer-executing plan reproduces a fresh same-flavour
    # compile of the artifact bit-for-bit, and stays within the
    # activation-quant envelope of the dequantise-first reference
    assert handle.engine.int_exec
    same = runtime.compile_model(cfg, q2, backend="lut")
    np.testing.assert_array_equal(after, np.asarray(same.forward(probe)))
    ref = runtime.compile_model(cfg, q2, backend="lut",
                                integer_resident=False, integer_exec=False)
    np.testing.assert_allclose(after, np.asarray(ref.forward(probe)),
                               atol=cellmod.hotswap._INT_EXEC_PROBE_TOL)
    assert handle.live_params() is not lp0       # cache invalidated


def test_hot_swap_strict_rejects_exec_mismatch(kwt_setup):
    cfg, params = kwt_setup
    handle = runtime.EngineHandle(
        runtime.compile_model(cfg, params, backend="float"))
    other = runtime.compile_model(cfg, params, backend="lut")
    with pytest.raises(ValueError, match="exec config"):
        handle.swap(other)
    assert handle.generation == 0                # untouched


def test_watcher_and_poll_and_swap(kwt_setup, tmp_path):
    cfg, _ = kwt_setup
    like = _packed(cfg, 0)
    handle = runtime.EngineHandle(
        runtime.compile_model(cfg, like, backend="lut"))
    probe = jnp.zeros((1,) + tuple(cfg.input_dim), jnp.float32)
    w = cellmod.CheckpointWatcher(str(tmp_path))
    assert w.poll() is None
    assert not cellmod.poll_and_swap(handle, w, like, probe)
    manager.save(str(tmp_path), 5, _packed(cfg, 3))
    assert w.poll() == 5
    assert cellmod.poll_and_swap(handle, w, like, probe)
    assert handle.generation == 1 and w.last_step == 5
    assert not cellmod.poll_and_swap(handle, w, like, probe)  # consumed


def test_watcher_wait_timeout_injected_clock(tmp_path):
    t = {"now": 0.0}
    slept = []

    def sleep(s):
        slept.append(s)
        t["now"] += s

    w = cellmod.CheckpointWatcher(str(tmp_path), poll_s=0.25,
                                  clock=lambda: t["now"], sleep=sleep)
    assert w.wait_for_new_step(timeout_s=1.0) is None
    assert slept and t["now"] >= 1.0


# ---------------------------------------------------------------------------
# checkpoint manager: latest-step discovery under partial writes
# ---------------------------------------------------------------------------

def test_latest_step_skips_partial_writes(tmp_path):
    d = str(tmp_path)
    manager.save(d, 3, {"w": jnp.ones((2,))})
    # in-flight tmp dir (pre-rename crash leftover)
    os.makedirs(os.path.join(d, "step_00000009.tmp-abcd1234"))
    # renamed but manifest-less (external partial copy)
    os.makedirs(os.path.join(d, "step_00000007"))
    # manifest present but payload shard missing
    os.makedirs(os.path.join(d, "step_00000008"))
    with open(os.path.join(d, "step_00000008", "manifest.json"), "w") as f:
        json.dump({"step": 8}, f)
    # corrupt (truncated) manifest
    os.makedirs(os.path.join(d, "step_00000011"))
    with open(os.path.join(d, "step_00000011", "manifest.json"), "w") as f:
        f.write('{"step": 11')
    # unparsable names must not crash the watcher
    os.makedirs(os.path.join(d, "step_garbage"))
    open(os.path.join(d, "step_"), "w").close()
    assert manager.latest_step(d) == 3
    manager.save(d, 12, {"w": jnp.ones((2,))})
    assert manager.latest_step(d) == 12


def test_latest_step_missing_dir():
    assert manager.latest_step("/nonexistent/ckpts") is None


# ---------------------------------------------------------------------------
# detector lane recycling (satellite)
# ---------------------------------------------------------------------------

def test_recycled_lane_must_not_inherit_detector_state():
    """Skipping the evict/join reset hands the next stream the previous
    one's refractory countdown and hysteresis latch — its own early
    keyword is silently suppressed.  The reset restores symmetry."""
    dcfg = det.DetectorConfig(smooth_hops=2, on_threshold=0.6,
                              off_threshold=0.4, refractory_hops=50)
    hot = jnp.asarray([[0.1, 0.9]])              # keyword-like posterior
    state = det.detector_init(dcfg, 1)
    fired_hops = []
    for _ in range(4):
        state, ev = det.detector_step(state, hot, dcfg)
        fired_hops.append(bool(ev["fired"][0]))
    assert any(fired_hops)                       # first stream fired

    # stream ends; lane recycled WITHOUT reset: the inherited hysteresis
    # latch + refractory suppress the new stream's identical keyword
    leaked = state
    for _ in range(4):
        leaked, ev = det.detector_step(leaked, hot, dcfg)
        assert not bool(ev["fired"][0])

    # with the reset, the new stream behaves exactly like the first one
    clean = det.detector_reset_lane(state, 0)
    fired2 = []
    for _ in range(4):
        clean, ev = det.detector_step(clean, hot, dcfg)
        fired2.append(bool(ev["fired"][0]))
    assert fired2 == fired_hops


def test_detector_reset_lane_accepts_index_array():
    dcfg = det.DetectorConfig()
    state = det.detector_init(dcfg, 4)
    state = {**state, "cooldown": state["cooldown"] + 9}
    state = det.detector_reset_lane(state, jnp.asarray([1, 3]))
    np.testing.assert_array_equal(np.asarray(state["cooldown"]),
                                  [9, 0, 9, 0])


# ---------------------------------------------------------------------------
# ServeCell + StreamLanes
# ---------------------------------------------------------------------------

def test_stream_lanes_lifecycle_and_ledger(kwt_setup):
    cfg, params = kwt_setup
    cell = cellmod.ServeCell(
        runtime.compile_model(cfg, params, backend="float"),
        slots=2, registry=telemetry.Registry())
    rng = np.random.RandomState(0)
    with cell:
        lanes = cell.stream_lanes(FCFG, det.DetectorConfig())
        lanes.join(0)
        lanes.join(1)
        with pytest.raises(AssertionError):
            lanes.join(0)                        # occupied
        for _ in range(3):
            lanes.hop(rng.randn(2, HOP).astype(np.float32))
        lanes.evict(1)
        lanes.hop(rng.randn(2, HOP).astype(np.float32))
        # partial trailing chunk: explicit per-lane ingest override
        lanes.hop(np.zeros((2, HOP), np.float32),
                  ingest=np.asarray([1, 0]))
        m = cell.metrics
        assert m.joins.value == 2 and m.evictions.value == 1
        assert m.hops.value == 3 * 2 + 1 + 1
        assert m.dropped_hops.value == 0
        assert lanes.free_lanes() == [1]


def test_stream_lanes_pipelined_matches_joint(kwt_setup):
    cfg, params = kwt_setup
    eng = runtime.compile_model(cfg, params, backend="float")
    cell = cellmod.ServeCell(eng, slots=2, registry=telemetry.Registry())
    rng = np.random.RandomState(2)
    with cell:
        a = cell.stream_lanes(FCFG, det.DetectorConfig())
        b = cell.stream_lanes(FCFG, det.DetectorConfig(), pipelined=True)
        for lanes in (a, b):
            lanes.join(0)
            lanes.join(1)
        for _ in range(4):
            c = rng.randn(2, HOP).astype(np.float32)
            ea, eb = a.hop(c), b.hop(c)
            np.testing.assert_array_equal(ea["score"], eb["score"])
            np.testing.assert_array_equal(ea["fired"], eb["fired"])


def test_stream_lanes_feature_ingest_matches_audio(kwt_setup):
    """Edge-featurised ingest: feeding the frames ``frontend_push``
    produces for a chunk is bit-identical to handing the cell the raw
    audio — the contract that lets edge devices own the MFCC stage."""
    cfg, params = kwt_setup
    eng = runtime.compile_model(cfg, params, backend="float")
    cell = cellmod.ServeCell(eng, slots=2, registry=telemetry.Registry())
    rng = np.random.RandomState(4)
    with cell:
        with pytest.raises(AssertionError):
            cell.stream_lanes(FCFG, det.DetectorConfig(),
                              feature_ingest=True, pipelined=True)
        a = cell.stream_lanes(FCFG, det.DetectorConfig())
        f = cell.stream_lanes(FCFG, det.DetectorConfig(),
                              feature_ingest=True)
        for lanes in (a, f):
            lanes.join(0)
            lanes.join(1)
        edge = features.frontend_init(FCFG, 2)  # the device-side frontend
        push = jax.jit(lambda s, c: features.frontend_push(s, c, FCFG))
        for _ in range(4):
            c = rng.randn(2, HOP).astype(np.float32)
            edge, frames = push(edge, c)
            ea, ef = a.hop(c), f.hop(frames)
            np.testing.assert_array_equal(ea["score"], ef["score"])
            np.testing.assert_array_equal(ea["fired"], ef["fired"])


def test_cell_swap_under_streaming_drops_nothing(kwt_setup, tmp_path):
    """Hot-swap between hops: lanes keep their ring positions, the hop
    ledger stays exact, and the post-swap engine serves the new params."""
    cfg, _ = kwt_setup
    like = _packed(cfg, 0)
    probe = jnp.zeros((1,) + tuple(cfg.input_dim), jnp.float32)
    cell = cellmod.ServeCell(
        runtime.compile_model(cfg, like, backend="lut"), slots=2,
        registry=telemetry.Registry(), watch_dir=str(tmp_path),
        watch_like=like, probe=probe)
    rng = np.random.RandomState(3)
    n_hops = 6
    with cell:
        lanes = cell.stream_lanes(FCFG, det.DetectorConfig())
        lanes.join(0)
        lanes.join(1)
        for h in range(n_hops):
            if h == 2:
                manager.save(str(tmp_path), 1, _packed(cfg, 9))
            assert cell.maybe_swap() == (h == 2)
            lanes.hop(rng.randn(2, HOP).astype(np.float32))
        m = cell.metrics
        assert cell.handle.generation == 1 and m.swaps.value == 1
        assert m.hops.value == n_hops * 2 and m.dropped_hops.value == 0
        # the embed ring advanced continuously across the swap
        want = min(n_hops, stream_engine.window_frames(cfg))
        assert int(lanes.state["embed"]["count"][0]) == want


def test_cell_watcher_requires_template_and_probe(kwt_setup):
    cfg, params = kwt_setup
    eng = runtime.compile_model(cfg, params, backend="float")
    with pytest.raises(AssertionError):
        cellmod.ServeCell(eng, slots=1, registry=telemetry.Registry(),
                          watch_dir="/tmp/nowhere")


# ---------------------------------------------------------------------------
# serve_common: crash-faithful telemetry flush (satellite)
# ---------------------------------------------------------------------------

def test_session_flushes_on_exception(tmp_path, capsys):
    out = str(tmp_path / "trace.json")
    with pytest.raises(RuntimeError, match="boom"):
        with serve_common.session(out) as (tracer, met):
            met.counter("serve_test_total").inc(3)
            with telemetry.span("doomed"):
                pass
            raise RuntimeError("boom")
    assert os.path.exists(out)
    assert os.path.exists(str(tmp_path / "trace.prom"))
    with open(str(tmp_path / "trace.metrics.json")) as f:
        assert json.load(f)["serve_test_total"]["value"] == 3
    assert "aborted=RuntimeError" in capsys.readouterr().out


def test_session_flushes_on_keyboard_interrupt(tmp_path):
    out = str(tmp_path / "trace.json")
    with pytest.raises(KeyboardInterrupt):
        with serve_common.session(out):
            raise KeyboardInterrupt
    assert os.path.exists(out)
    assert os.path.exists(str(tmp_path / "trace.metrics.json"))


def test_session_isolates_artifact_save_failures(tmp_path, monkeypatch):
    """A failing trace write must not eat the metric exports."""
    out = str(tmp_path / "trace.json")
    monkeypatch.setattr(
        telemetry.Tracer, "save",
        lambda self, p: (_ for _ in ()).throw(OSError("disk full")))
    with serve_common.session(out) as (tracer, met):
        met.gauge("serve_test_gauge").set(7.0)
    assert not os.path.exists(out)               # trace save failed...
    with open(str(tmp_path / "trace.metrics.json")) as f:   # ...metrics safe
        assert json.load(f)["serve_test_gauge"]["value"] == 7.0


def test_session_disabled_without_out_path():
    with serve_common.session(None) as (tracer, met):
        assert tracer is None
        assert telemetry.active_tracer() is None
