"""End-to-end system test: the paper's full pipeline on KWT-Tiny.

Reproduces the paper's staging (§III-§VI):
  1. train KWT-Tiny on the synthetic 2-class keyword task;
  2. post-training power-of-2 quantisation at the Table V exponents;
  3. the "+Hardware" LUT path (LUT softmax + LUT GELU);
and asserts the accuracy ordering of Table IX:
  float >= quantised >= quantised+LUT, each within a few points.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core import calibrate
from repro.data import pipeline
from repro.models import kwt
from repro.optim import adamw


@pytest.fixture(scope="module")
def trained_kwt():
    cfg = registry.get("kwt-tiny").config
    hp = adamw.HParams(lr=3e-3, warmup_steps=20, total_steps=300,
                       weight_decay=0.0)
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    state = adamw.init(params, hp)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(kwt.loss_fn)(params, batch, cfg)
        params, state, _ = adamw.update(grads, state, params, hp,
                                        scan_stacked=False)
        return params, state, loss

    for i in range(300):
        batch = pipeline.keyword_batch(0, i, batch=64,
                                       input_dim=cfg.input_dim)
        params, state, loss = step(params, state, batch)
    return cfg, params


def _accuracy(cfg, params, n=512):
    correct = total = 0
    for batch in pipeline.gsc_eval_set(0, n=n, input_dim=cfg.input_dim):
        pred = jnp.argmax(kwt.forward(params, batch["mfcc"], cfg), -1)
        correct += int(jnp.sum(pred == batch["labels"]))
        total += int(batch["labels"].size)
    return correct / total


def test_kwt_tiny_end_to_end(trained_kwt):
    cfg, params = trained_kwt
    acc_float = _accuracy(cfg, params)
    # the synthetic surrogate is tuned to land near the paper's 87.2%
    # (overlapping classes); 0.75 guards regression without overfitting CI
    assert acc_float > 0.75, f"float accuracy {acc_float}"

    # --- stage 2: PTQ, Table V best pair (weights 2^6, inputs 2^5) ---
    from repro import runtime
    eng_q = runtime.compile_model(cfg, params, backend="float",
                                  recipe=runtime.QuantRecipe.from_config(cfg))
    qbytes, fbytes = eng_q.quantized_bytes
    assert qbytes < 2048           # ~1.6 kB of int8 weights (Table IX)
    acc_q = _accuracy(eng_q.exec_cfg, eng_q.params)
    assert acc_q > acc_float - 0.10, (acc_float, acc_q)

    # --- stage 3: +Hardware (LUT softmax + LUT GELU, Q8.24) ---
    eng_h = runtime.compile_model(cfg, params, backend="lut")
    acc_h = _accuracy(eng_h.exec_cfg, eng_h.params)
    assert acc_h > acc_q - 0.08, (acc_q, acc_h)
    print(f"\nKWT-Tiny accuracies: float={acc_float:.3f} "
          f"quantised={acc_q:.3f} +LUT={acc_h:.3f}")


def test_scale_factor_sweep_prefers_mixed(trained_kwt):
    """Table V reproduction: (64, 32) should beat (8, 8) clearly."""
    cfg, params = trained_kwt
    batches = [(b["mfcc"], b["labels"])
               for b in pipeline.gsc_eval_set(0, n=256,
                                              input_dim=cfg.input_dim)]
    res = calibrate.sweep_scale_factors(
        lambda p, x: kwt.forward(p, x, cfg), params, batches,
        pairs=[(3, 3), (6, 5)])
    low, best = res[0].accuracy, res[1].accuracy
    assert best >= low
