"""Distribution-layer tests that need >1 device: run small sharded-vs-local
equivalence checks in a subprocess with forced host devices (the main
pytest process must keep the real single-device topology)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.models import transformer as T

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_sharded_dense_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.dist import ctx
        from repro.models import transformer as T
        cfg = registry.get('granite-8b').smoke
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
                 'labels': jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size)}
        ref, gref = jax.value_and_grad(T.loss_fn)(params, batch, cfg)
        mesh = jax.make_mesh((4, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with mesh, ctx.mesh_context(('data',)):
            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), T.param_specs(cfg),
                                is_leaf=lambda x: isinstance(x, P))
            ps = jax.device_put(params, p_sh)
            got, ggot = jax.jit(jax.value_and_grad(T.loss_fn),
                                static_argnums=2)(ps, batch, cfg)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(gref), jax.tree.leaves(ggot)))
        print('LOSSDIFF', abs(float(ref) - float(got)), 'GRADDIFF', d)
    """)
    loss_diff = float(out.split("LOSSDIFF")[1].split()[0])
    grad_diff = float(out.split("GRADDIFF")[1].split()[0])
    assert loss_diff < 1e-4
    assert grad_diff < 1e-2       # bf16 grads, different reduction orders


def test_sharded_moe_matches_local_dropfree():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.dist import ctx
        from repro.models import transformer as T
        cfg = registry.get('granite-moe-3b-a800m').smoke.with_(capacity_factor=8.0)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
                 'labels': jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size)}
        ref = T.loss_fn(params, batch, cfg)
        mesh = jax.make_mesh((4, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with mesh, ctx.mesh_context(('data',)):
            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), T.param_specs(cfg),
                                is_leaf=lambda x: isinstance(x, P))
            ps = jax.device_put(params, p_sh)
            got = jax.jit(T.loss_fn, static_argnums=2)(ps, batch, cfg)
        print('LOSSDIFF', abs(float(ref) - float(got)))
    """)
    assert float(out.split("LOSSDIFF")[1].split()[0]) < 1e-4


def test_int8_kv_cache_decode():
    cfg = registry.get("internlm2-1.8b").smoke.with_(
        quant=QuantConfig(quantize_kv_cache=True))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ref = T.forward(params, toks, cfg)[:, -1]
    state = T.init_decode_state(cfg, 2, max_len=32)
    assert state["layers"]["k"].dtype == jnp.int8       # storage halved
    _, state = T.prefill(params, toks[:, :-1], cfg, state)
    lg, _ = T.decode_step(params, toks[:, -1], cfg, state)
    rel = float(jnp.max(jnp.abs(lg - ref))) / float(jnp.max(jnp.abs(ref)))
    agree = float(jnp.mean(
        (jnp.argmax(lg, -1) == jnp.argmax(ref, -1)).astype(jnp.float32)))
    assert rel < 0.05 and agree == 1.0


def test_rwkv_head_pad_function_preserving():
    cfg = registry.get("rwkv6-3b").smoke
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    ref = T.forward(params, toks, cfg)
    cfgp = cfg.with_(rwkv_head_pad=True)
    pp = T.init_params(cfgp, jax.random.PRNGKey(0))

    def graft(pad_leaf, ref_leaf):
        if pad_leaf.shape == ref_leaf.shape:
            return ref_leaf
        out = jnp.zeros_like(pad_leaf)
        return out.at[tuple(slice(0, s) for s in ref_leaf.shape)].set(ref_leaf)

    pp = jax.tree.map(graft, pp, params)
    got = T.forward(pp, toks, cfgp)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_surgeon_ranks_layers():
    from repro.data import pipeline
    from repro.models import kwt
    from repro.tools import surgeon

    cfg = registry.get("kwt-1").config.with_(n_layers=3)
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    batches = [pipeline.keyword_batch(0, i, batch=16,
                                      input_dim=cfg.input_dim,
                                      n_classes=cfg.n_classes)
               for i in range(2)]
    base, scores = surgeon.ablation_scores(params, cfg, batches, kwt.loss_fn)
    assert len(scores) == 3
    plan = surgeon.shrink_plan(scores, keep=1)
    assert len(plan) == 2


def test_compressed_grad_sync_error_feedback():
    """int8 ring all-reduce with error feedback tracks the exact mean over
    many steps (bias telescopes), and the wire payload is s8."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.dist import compress
        mesh = jax.make_mesh((2, 4), ('pod', 'data'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        key = jax.random.PRNGKey(0)
        grads = {'w': jax.random.normal(key, (64, 64))}
        err = compress.init_error_state(grads)
        # one-shot sum correctness vs exact (values identical across pods
        # here because inputs are replicated -> sum = 2x)
        synced, err1 = compress.compressed_grad_sync(grads, err, mesh)
        exact = grads['w']
        rel = float(jnp.max(jnp.abs(synced['w'] - exact))) / float(jnp.max(jnp.abs(exact)))
        # error feedback: accumulate residual-corrected means over K steps
        acc_c = jnp.zeros_like(exact); errk = err
        for k in range(16):
            g = {'w': grads['w'] * (1.0 + 0.01 * k)}
            s, errk = compress.compressed_grad_sync(g, errk, mesh)
            acc_c = acc_c + s['w']
        acc_e = sum(grads['w'] * (1.0 + 0.01 * k) for k in range(16))
        drift = float(jnp.max(jnp.abs(acc_c - acc_e))) / float(jnp.max(jnp.abs(acc_e)))
        # wire check: the compiled sync must move s8 collective-permutes
        txt = jax.jit(lambda g, e: compress.compressed_grad_sync(g, e, mesh)) \
            .lower(grads, err).compile().as_text()
        has_s8 = 's8[' in txt and 'collective-permute' in txt
        print('REL', rel, 'DRIFT', drift, 'S8WIRE', has_s8)
    """)
    rel = float(out.split("REL")[1].split()[0])
    drift = float(out.split("DRIFT")[1].split()[0])
    assert rel < 0.02          # single-step quantisation error bound
    assert drift < 0.02        # error feedback: no accumulation over K steps
    assert "True" in out.split("S8WIRE")[1]
