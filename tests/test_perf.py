"""repro.perf + telemetry.flight: the PR-9 contracts.

* the static cost model's FLOPs/bytes agree with hand-counted analytic
  formulas for a linear layer and the full KWT block (projections +
  scores + MLP + head), exactly;
* matmul FLOPs are invariant across float/lut_float/lut/pallas for
  identical math (the backends change softmax/GELU realisation and
  weight residency, never the linear algebra);
* the ledger round-trips entries and the regression gate trips on a 2×
  latency / any-ROM-growth regression and stays quiet on healthy runs
  (including the ``python -m repro.perf regress`` exit codes);
* the flight recorder's ring wraps at capacity, each anomaly dumps
  exactly once per incident, and the post-mortem attributes slow hops
  to a named stage;
* ``latency_summary`` reports n=0 on empty reservoirs instead of
  raising (the cold-cell export path).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf, runtime, telemetry
from repro.configs import registry
from repro.models import kwt, layers
from repro.perf import __main__ as perf_cli
from repro.stream import features
from repro.telemetry.cell import make_cell_metrics
from repro.telemetry.flight import FlightConfig, FlightRecorder

CFG = registry.get("kwt-tiny").smoke


@pytest.fixture(scope="module")
def params():
    return kwt.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engines(params):
    return {b: runtime.compile_model(CFG, params, backend=b)
            for b in ("float", "lut_float", "lut", "pallas")}


# ---------------------------------------------------------------------------
# cost model: hand-counted ground truth
# ---------------------------------------------------------------------------

def test_linear_flops_bytes_hand_counted():
    m, k, n = 5, 7, 11
    x = jnp.zeros((m, k), jnp.float32)
    w = jnp.zeros((k, n), jnp.float32)
    rep = perf.program_cost(
        lambda a, b: layers.linear(a, b, "mk,kn->mn"), x, w)
    assert rep.flops == 2 * m * n * k                  # one dot, 2MNK
    assert rep.bytes == 4 * (m * k + k * n + m * n)    # f32 in + out
    assert rep.matmul_flops == rep.flops


@pytest.mark.parametrize("batch", [1, 4])
def test_kwt_matmul_flops_hand_counted(engines, batch):
    """Full KWT-Tiny forward vs the analytic per-layer matmul count."""
    f, t_in = CFG.input_dim
    d, h = CFG.d_model, CFG.n_heads
    dh = CFG.resolved_head_dim
    t = t_in + 1                                   # + cls token
    mlp = CFG.d_ff
    per_layer = (3 * 2 * t * d * (h * dh)          # wq/wk/wv projections
                 + 2 * 2 * h * t * t * dh          # scores + attn @ v
                 + 2 * t * (h * dh) * d            # wo
                 + 2 * t * d * mlp + 2 * t * mlp * d)   # mlp w1/w2
    expect = batch * (2 * t_in * d * f             # embed_frames linear
                      + CFG.n_layers * per_layer
                      + 2 * d * CFG.n_classes)     # cls head
    rep = perf.engine_cost(engines["float"], batch=batch)
    assert rep.matmul_flops == expect


def test_matmul_flops_invariant_across_backends(engines):
    """Identical math on every backend: the LUT/Pallas plans re-route
    softmax/GELU (and pay unpack), but dot_general work is pinned."""
    reps = {b: perf.engine_cost(e, batch=2) for b, e in engines.items()}
    counts = {b: r.matmul_flops for b, r in reps.items()}
    assert len(set(counts.values())) == 1, counts


def test_unpack_stage_only_for_int_resident(engines):
    stages_f = perf.engine_cost(engines["float"]).by_stage()
    stages_q = perf.engine_cost(engines["lut"]).by_stage()
    assert "unpack" not in stages_f
    assert stages_q["unpack"].flops > 0
    # unpack work scales with params, not batch
    stages_q8 = perf.engine_cost(engines["lut"], batch=8).by_stage()
    assert stages_q8["unpack"].flops == stages_q["unpack"].flops


def test_stage_split_matches_span_names(engines):
    """Stages mirror the telemetry span vocabulary: embed/encode for the
    offline forward, + featurise for the audio-ingest streaming hop."""
    rep = perf.engine_cost(engines["float"], batch=1)
    assert set(rep.by_stage()) == {"embed", "encode"}
    fcfg = features.FrontendConfig()
    hop = perf.stream_hop_cost(engines["float"], fcfg, batch=2)
    assert "featurise" in hop.by_stage()
    hop_f = perf.stream_hop_cost(engines["float"], fcfg, batch=2,
                                 feature_ingest=True)
    assert "featurise" not in hop_f.by_stage()


def test_softmax_gelu_rows_and_report_shape(engines):
    rep = perf.engine_cost(engines["lut"], batch=1)
    ops = {op for (_, op) in rep.lines}
    assert {"softmax", "gelu", "matmul", "norm"} <= ops
    rows = rep.rows(perf.PAPER_MCU)
    assert all({"stage", "op", "flops", "bytes_moved",
                "arithmetic_intensity", "est_cycles"} <= set(r)
               for r in rows)
    assert "est_cycles" in rep.table(perf.PAPER_MCU)
    w = rep.stage_weights(perf.PAPER_MCU)
    assert abs(sum(w.values()) - 1.0) < 1e-9 and "unpack" in w


# ---------------------------------------------------------------------------
# roofline machine model
# ---------------------------------------------------------------------------

def test_machine_model_math():
    m = perf.MachineModel(name="toy", peak_flops=100.0, mem_bw=10.0,
                          clock_hz=50.0)
    assert m.ridge == 10.0
    assert m.attainable(5.0) == 50.0           # memory side
    assert m.attainable(20.0) == 100.0         # compute side
    assert m.verdict(5.0) == "memory-bound"
    assert m.verdict(20.0) == "compute-bound"
    assert m.time_s(200.0, 10.0) == 2.0        # compute term dominates
    assert m.cycles(200.0, 10.0) == 100.0


def test_roofline_terms_keys_and_verdict():
    m = perf.MachineModel(name="toy", peak_flops=100.0, mem_bw=10.0)
    row = perf.roofline_terms(50.0, 100.0, measured_s=2.0, machine=m)
    assert row["bound"] == "memory-bound"
    assert row["achieved_flops_per_s"] == 25
    assert row["achieved_pct_of_roof"] == 500.0      # roof = 0.5*10
    assert row["achieved_pct_of_peak"] == 25.0
    assert {"flops", "bytes_moved", "arithmetic_intensity"} <= set(row)


def test_calibrate_measures_positive_envelope():
    m = perf.calibrate(n=128, stream_mb=4, reps=1)
    assert m.peak_flops > 0 and m.mem_bw > 0 and m.source == "measured"
    assert m.id.startswith("measured-")


# ---------------------------------------------------------------------------
# ledger + regression gate
# ---------------------------------------------------------------------------

PROV = {"git_commit": "t", "jax_version": "-", "device": "-",
        "timestamp": "-", "calibration": None}


def _seed(path, latencies, rom=1500):
    perf.append(path, [perf.entry("kwt-tiny", "lut", 64, la,
                                  "us_per_forward", rom_bytes=rom,
                                  prov=PROV) for la in latencies])


def test_ledger_round_trip(tmp_path):
    path = str(tmp_path / "h.jsonl")
    e = perf.entry("kwt-tiny", "lut", 64, 612.5, "us_per_forward",
                   rom_bytes=1500, extra={"bound": "memory-bound"},
                   prov=PROV)
    assert perf.append(path, e) == 1
    assert perf.append(path, [e, e]) == 2
    back = perf.read(path)
    assert len(back) == 3 and back[0] == e
    assert perf.read(str(tmp_path / "missing.jsonl")) == []


def test_regress_no_trip_on_healthy(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed(path, [600.0, 610.0, 605.0, 608.0])
    v = perf.regress(path)
    assert v.ok and v.checked == 1 and not v.failures


def test_regress_trips_on_latency(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed(path, [600.0, 610.0, 605.0, 1300.0])     # >2x the median
    v = perf.regress(path)
    assert not v.ok and "latency" in v.failures[0]


def test_regress_trips_on_any_rom_growth(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed(path, [600.0, 610.0])
    perf.append(path, perf.entry("kwt-tiny", "lut", 64, 600.0,
                                 "us_per_forward", rom_bytes=1501,
                                 prov=PROV))
    v = perf.regress(path)
    assert not v.ok and "rom_bytes" in v.failures[0]


def test_regress_first_entry_seeds_baseline(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed(path, [600.0])
    v = perf.regress(path)
    assert v.ok and v.checked == 0 and v.skipped == 1


def test_regress_baseline_is_median_not_last(tmp_path):
    """One noisy prior run must not move the baseline."""
    path = str(tmp_path / "h.jsonl")
    _seed(path, [600.0, 605.0, 6000.0, 610.0])     # spike mid-history
    assert perf.regress(path).ok


def test_regress_cli_exit_codes(tmp_path):
    bad = str(tmp_path / "bad.jsonl")
    _seed(bad, [600.0, 610.0, 1300.0])
    assert perf_cli.main(["regress", "--history", bad]) == 1
    good = str(tmp_path / "good.jsonl")
    _seed(good, [600.0, 610.0, 605.0])
    assert perf_cli.main(["regress", "--history", good]) == 0
    assert perf_cli.main(["regress", "--selftest"]) == 0


def test_provenance_fields():
    p = perf.provenance(perf.PAPER_MCU)
    assert {"git_commit", "jax_version", "device", "timestamp",
            "calibration"} <= set(p)
    assert p["calibration"] == perf.PAPER_MCU.id


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

@pytest.fixture()
def flight(tmp_path):
    m = make_cell_metrics(telemetry.Registry())
    fr = FlightRecorder(m, FlightConfig(capacity=8, shed_spike=3,
                                        min_hops=4,
                                        dump_dir=str(tmp_path)),
                        stage_weights={"encode": 0.7, "featurise": 0.3})
    return m, fr


def test_flight_ring_wraps(flight):
    _, fr = flight
    for i in range(20):
        fr.record_hop(float(i))
    assert len(fr) == 8
    win = fr.window()
    assert [r.seq for r in win] == list(range(12, 20))
    assert win[-1].duration_ms == 19.0


def test_flight_shed_spike_dumps_once(flight):
    m, fr = flight
    for _ in range(4):
        fr.record_hop(1.0)
    m.rejected.inc(3)
    path = fr.record_hop(1.0)
    assert path is not None
    art = json.load(open(path))
    assert art["reason"] == "shed_spike"
    assert art["admission"]["rejected_in_window"] == 3
    # still tripped: no second dump until the window clears
    assert fr.record_hop(1.0) is None
    # spike rolls out of the 8-hop window -> re-arms -> a NEW spike dumps
    for _ in range(8):
        assert fr.record_hop(1.0) is None
    m.rejected.inc(3)
    assert fr.record_hop(1.0) is not None
    assert len(fr.dumps) == 2


def test_flight_slo_burn_uses_budget_gauge(flight):
    m, fr = flight
    m.latency_budget.set(10.0)
    for _ in range(3):
        assert fr.record_hop(50.0) is None     # below min_hops: no dump
    path = fr.record_hop(50.0)
    assert path is not None and "slo_burn" in path
    att = json.load(open(path))["attribution"]
    assert att["slowest_stage"] == "encode"    # 0.7 weight wins
    assert att["method"] == "cost-model-weights"
    assert att["stage_ms"]["encode"] == pytest.approx(35.0)


def test_flight_swap_failure_via_check(flight):
    m, fr = flight
    for _ in range(2):
        fr.record_hop(1.0)
    assert fr.check() is None
    m.swap_failures.inc()                      # probe-parity refusal
    path = fr.check()                          # between hops, no new slot
    assert path is not None
    assert json.load(open(path))["reason"] == "swap_failure"
    assert len(fr) == 2                        # check() consumed no slot


def test_flight_attribution_prefers_measured_spans(flight):
    m, fr = flight
    m.latency_budget.set(10.0)
    for _ in range(4):
        fr.record_hop(50.0, spans={"featurise": 40.0, "encode": 9.0})
    att = fr.attribution()
    assert att["method"] == "measured-spans"
    assert att["slowest_stage"] == "featurise"


def test_flight_lazy_stage_weights_resolve_once(tmp_path):
    m = make_cell_metrics(telemetry.Registry())
    calls = []

    def weights():
        calls.append(1)
        return {"encode": 1.0}

    fr = FlightRecorder(m, FlightConfig(capacity=4,
                                        dump_dir=str(tmp_path)),
                        stage_weights=weights)
    fr.record_hop(1.0)
    fr.dump("manual")
    fr.dump("manual")
    assert len(calls) == 1                     # resolved once, then cached


def test_flight_dump_artifact_schema(flight):
    m, fr = flight
    for i in range(6):
        fr.record_hop(1.0 + i)
    path = fr.dump("manual")
    art = json.load(open(path))
    assert {"reason", "provenance", "attribution", "admission",
            "hotswap", "trace", "hop_latency"} <= set(art)
    assert len(art["trace"]) == 6
    assert art["provenance"]["git_commit"]
    assert os.path.exists(path)


# ---------------------------------------------------------------------------
# integration: Engine.describe(cost=True), empty latency_summary
# ---------------------------------------------------------------------------

def test_describe_cost_appends_table(engines):
    out = engines["lut"].describe(cost=True)
    assert "cost/fwd" in out and "est_cycles" in out
    assert "| unpack |" in out                 # the paper-style table


def test_latency_summary_empty_reports_n0():
    s = telemetry.latency_summary([], unit="ms")
    assert s == {"n": 0, "mean_ms": 0.0, "p50_ms": 0.0, "p95_ms": 0.0,
                 "p99_ms": 0.0}
    # cold histogram (no observations yet) exports without raising
    h = telemetry.Registry().histogram("cold_ms", unit="ms")
    assert h.summary()["n"] == 0
    assert np.isfinite(list(s.values())[1])


def test_latency_summary_count_override_empty():
    s = telemetry.latency_summary([], unit="us", count=7)
    assert s["n"] == 7 and s["p99_us"] == 0.0
