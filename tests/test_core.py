"""Core library tests: fixed point, LUTs, approximations, quantisation.

Property tests (hypothesis) pin the system's invariants; exact-value tests
pin the paper's constants (320-entry tables, 2.69 kB ROM, thresholds
1.595 / -1.857, Table V exponents).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:
    # hypothesis is a test extra (pip install '.[test]'); without it the
    # property tests skip and the exact-value tests still run.
    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install '.[test]')")(fn)
        return deco

    class _StrategyStub:
        """Stands in for hypothesis.strategies: @given arguments are built
        at decoration time but never drawn from once the test is skipped."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import approx, calibrate, fixedpoint as fxp, lut, quant


# ---------------------------------------------------------------------------
# fixed point
# ---------------------------------------------------------------------------

@given(st.floats(min_value=-127.9, max_value=127.9, allow_nan=False))
def test_fixed_roundtrip(x):
    q = fxp.to_fixed(jnp.float32(x))
    assert abs(float(fxp.to_float(q)) - x) <= 2 ** -24 + abs(x) * 1e-6


@given(st.floats(min_value=0, max_value=1), st.floats(min_value=0, max_value=1))
def test_fixed_mul_bounded_domain(a, b):
    fa, fb = fxp.to_fixed(jnp.float32(a)), fxp.to_fixed(jnp.float32(b))
    got = float(fxp.to_float(fxp.fixed_mul(fa, fb)))
    assert abs(got - a * b) < 1e-6


@given(st.integers(min_value=1, max_value=2**31 - 1))
def test_ilog2(x):
    assert int(fxp.ilog2(jnp.int32(x))) == int(np.floor(np.log2(x)))


def test_fixed_saturation():
    assert int(fxp.to_fixed(jnp.float32(1e9))) == 2**31 - 1


_INT32_MAX, _INT32_MIN = 2**31 - 1, -(2**31)


def test_to_fixed_saturation_edges():
    """Round-trip saturation at the Q8.24 representable range [-128, 128)."""
    assert int(fxp.to_fixed(jnp.float32(128.0))) == _INT32_MAX
    assert int(fxp.to_fixed(jnp.float32(-128.0))) == _INT32_MIN
    assert int(fxp.to_fixed(jnp.float32(-129.5))) == _INT32_MIN
    assert float(fxp.to_float(jnp.int32(_INT32_MIN))) == -128.0
    # the largest f32 below 128 still fits and round-trips exactly
    # (x * 2^24 is an integer at this magnitude: f32 ulp(128) = 2^-16)
    x = np.nextafter(np.float32(128.0), np.float32(0.0))
    q = int(fxp.to_fixed(jnp.float32(x)))
    assert q <= _INT32_MAX
    assert float(fxp.to_float(jnp.int32(q))) == float(x)


@given(st.floats(min_value=128.0, max_value=3e38))
def test_to_fixed_saturates_above_range(x):
    assert int(fxp.to_fixed(jnp.float32(x))) == _INT32_MAX
    assert int(fxp.to_fixed(jnp.float32(-x))) == _INT32_MIN


@given(st.integers(min_value=0, max_value=fxp.ONE),
       st.integers(min_value=0, max_value=fxp.ONE))
def test_fixed_mul_exact_in_unit_domain(qa, qb):
    """The documented precondition: for |a|,|b| <= 1.0 the 12/12-limb
    product sits within 2 LSB of the wide (a*b)>>24, never above it."""
    got = int(fxp.fixed_mul(jnp.int32(qa), jnp.int32(qb)))
    exact = (qa * qb) >> fxp.FRAC_BITS
    assert 0 <= exact - got <= 2


def test_fixed_mul_unit_boundary():
    """|a|,|b| at and just above 1.0 in Q8.24 (the exactness boundary)."""
    one = fxp.ONE
    assert int(fxp.fixed_mul(jnp.int32(one), jnp.int32(one))) == one
    assert int(fxp.fixed_mul(jnp.int32(one), jnp.int32(-one))) == -one
    assert int(fxp.fixed_mul(jnp.int32(one), jnp.int32(one // 2))) == one // 2
    # just above 1.0 the limb split still tracks the wide product ...
    for qa in (one + 1, one + 4096, 3 * one // 2):
        exact = (qa * qa) >> fxp.FRAC_BITS
        got = int(fxp.fixed_mul(jnp.int32(qa), jnp.int32(qa)))
        assert 0 <= exact - got <= 2, qa
    # ... but far outside the precondition the partial products wrap
    # int32 (ah*bh ~ 2^37 at |a|=100) — why the bound exists.
    big = fxp.to_fixed(jnp.float32(100.0))
    exact = (int(big) * int(big)) >> fxp.FRAC_BITS
    assert abs(int(fxp.fixed_mul(big, big)) - exact) > fxp.ONE


def test_fixed_shift_mul_saturates():
    """Regression: the left-shift path saturates instead of wrapping."""
    a = fxp.to_fixed(jnp.float32(8.0))                  # 2^27
    assert int(fxp.fixed_shift_mul(a, 5)) == _INT32_MAX  # 8 * 2^5 = 256
    assert int(fxp.fixed_shift_mul(-a, 5)) == _INT32_MIN
    # in-range shifts are the exact power-of-2 multiply
    v = fxp.to_fixed(jnp.float32(1.25))
    assert int(fxp.fixed_shift_mul(v, 3)) == int(v) << 3
    assert int(fxp.fixed_shift_mul(v, 0)) == int(v)
    assert int(fxp.fixed_shift_mul(v, -2)) == int(v) >> 2
    # the exact boundary: the largest magnitude that still fits
    lim = _INT32_MAX >> 4
    assert int(fxp.fixed_shift_mul(jnp.int32(lim), 4)) == lim << 4
    assert int(fxp.fixed_shift_mul(jnp.int32(lim + 1), 4)) == _INT32_MAX


@given(st.integers(min_value=_INT32_MIN, max_value=_INT32_MAX),
       st.integers(min_value=0, max_value=8))
def test_fixed_shift_mul_saturation_property(q, s):
    got = int(fxp.fixed_shift_mul(jnp.int32(q), s))
    assert got == max(min(q << s, _INT32_MAX), _INT32_MIN)


# ---------------------------------------------------------------------------
# LUT bank: the paper's ROM, bit for bit
# ---------------------------------------------------------------------------

def test_rom_matches_paper():
    bank = lut.make_lut_bank()
    assert bank.exp_f32.shape == (320,)          # eq 11: 320 entries
    assert bank.inv_f32.shape == (320,)          # eq 12
    assert bank.gelu_f32.shape == (32,)          # eq 13: 32 entries
    assert bank.rom_bytes == (320 + 320 + 32) * 4  # 2.69 kB (paper §VI)
    assert abs(bank.rom_bytes / 1024 - 2.6) < 0.1
    # eq 11: LUT1[z*32] ~= e^-z
    np.testing.assert_allclose(bank.exp_f32[64], np.exp(-2.0), rtol=1e-6)
    # eq 12: LUT2[z*32 - 1] ~= 1/z
    np.testing.assert_allclose(bank.inv_f32[63], 0.5, rtol=1e-6)


@given(st.floats(min_value=0.01, max_value=120.0))
def test_reciprocal_range_reduced(v):
    bank = lut.make_lut_bank()
    got = float(fxp.to_float(lut.reciprocal_q24(fxp.to_fixed(jnp.float32(v)),
                                                bank)))
    assert got == pytest.approx(1.0 / v, rel=0.04)


# ---------------------------------------------------------------------------
# approximations
# ---------------------------------------------------------------------------

@given(st.integers(2, 64), st.integers(0, 10**6))
def test_softmax_lut_close_and_normalised(k, seed):
    # analytic worst case: floor-binned exp LUT -> (1 - e^{-1/32}) ~ 3.1%
    # relative per entry; absolute error bounded by ~0.04 after the divide.
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, k)) * 3
    ref = jax.nn.softmax(x, -1)
    for mode in ("lut", "lut_fixed"):
        got = approx.softmax(x, mode=mode)
        assert float(jnp.max(jnp.abs(got - ref))) < 0.045
        assert float(jnp.max(jnp.abs(got.sum(-1) - 1))) < 0.045


def test_softmax_fixed_long_rows():
    # beyond the paper's K=27: int32 pre-shift keeps the pipeline sane
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32768)) * 3
    got = approx.softmax(x, mode="lut_fixed")
    assert float(jnp.max(jnp.abs(got.sum(-1) - 1))) < 0.08


def test_gelu_thresholds():
    # paper Fig 7: identity above 1.595, zero below -1.857
    x = jnp.asarray([2.0, 10.0, -2.0, -10.0, 0.0])
    y = approx.gelu(x, mode="lut")
    assert float(y[0]) == 2.0 and float(y[1]) == 10.0
    assert float(y[2]) == 0.0 and float(y[3]) == 0.0
    xs = jnp.linspace(-4, 4, 801)
    err = jnp.abs(approx.gelu(xs, "lut") - jax.nn.gelu(xs, approximate=False))
    assert float(jnp.max(err)) < 0.09       # dominated by the 1.595 tail cut


def test_masked_softmax_structural():
    s = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
    mask = jnp.asarray([[True, True, False, False]])
    for mode in ("exact", "lut", "lut_fixed"):
        p = approx.masked_softmax(s, mask, mode)
        assert float(jnp.abs(p[0, 2]) + jnp.abs(p[0, 3])) == 0.0
        assert float(jnp.sum(p)) == pytest.approx(1.0, abs=0.02)


@given(st.floats(-20, 20))
def test_silu_softplus_lut(v):
    x = jnp.float32(v)
    assert float(jnp.abs(approx.silu(x, "lut") - jax.nn.silu(x))) < 0.06
    assert float(jnp.abs(approx.softplus(x, "lut") - jax.nn.softplus(x))) < 0.06


# ---------------------------------------------------------------------------
# quantisation (eq 9, Table V)
# ---------------------------------------------------------------------------

@given(st.integers(3, 6), st.integers(0, 10**6))
def test_quantize_po2_error_bound(y, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 16)) * 0.4
    w = jnp.clip(w, -0.9, 0.9)
    q = quant.quantize_po2(w, y)
    # floor quantisation: error in [0, 2^-y)
    err = w - q.dequantize()
    assert float(jnp.min(err)) >= -1e-6
    assert float(jnp.max(err)) <= 2.0 ** -y + 1e-6


def test_choose_exponent_no_overflow():
    w = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.3
    y = quant.choose_exponent(w)
    q = quant.quantize_po2(w, y)
    # no positive saturation (floor of negatives may legitimately hit -128)
    assert int(jnp.max(q.values.astype(jnp.int32))) <= 127
    assert int(jnp.min(q.values.astype(jnp.int32))) >= -128
    q2 = quant.quantize_po2(w, y + 2)   # over-scaled -> saturates
    assert int(jnp.max(jnp.abs(q2.values.astype(jnp.int32)))) >= 127


def test_quantize_po2_narrowest_dtype_and_saturation_edges():
    """Regression: bits<8 no longer widens to int16 — storage is the
    narrowest dtype (int8 up to 8 bits, nibble-packed below 5) and the
    cast saturates at the true bits-wide edges ±(2^(bits-1)-1) / -2^(b-1)."""
    w = jnp.asarray([[1e7, -1e7], [0.9, -0.9]])
    for bits, dtype, packed in ((8, jnp.int8, False), (6, jnp.int8, False),
                                (5, jnp.int8, False), (4, jnp.uint8, True),
                                (2, jnp.uint8, True), (16, jnp.int16, False)):
        q = quant.quantize_po2(w, 0, bits=bits)
        assert q.values.dtype == dtype, bits
        assert q.packed is packed and q.shape == (2, 2)
        lo, hi = quant.int_range(bits)
        vals = q.int_values()
        assert int(vals.max()) == hi and int(vals.min()) == lo, bits
    # the positive edge is reachable exactly (no off-by-one at +hi)
    q4 = quant.quantize_po2(jnp.asarray([7.0, -8.0, 7.4, -8.6]), 0, bits=4)
    assert [int(v) for v in q4.int_values()] == [7, -8, 7, -8]


@given(st.integers(0, 33), st.integers(2, 4), st.integers(0, 10**6))
def test_pack_po2_roundtrip_property(n, bits, seed):
    """Codec property: exact int round-trip on odd lengths and empties,
    with the packed byte count always ceil(n/2)."""
    lo, hi = quant.int_range(bits)
    vals = jax.random.randint(jax.random.PRNGKey(seed), (n,), lo, hi + 1,
                              dtype=jnp.int32).astype(jnp.int8)
    packed = quant.pack_po2(vals, bits)
    assert packed.dtype == jnp.uint8
    assert packed.size == quant.packed_length(n, bits) == (n + 1) // 2
    back = quant.unpack_po2(packed, bits, (n,))
    assert back.dtype == jnp.int8
    assert bool(jnp.array_equal(back, vals))


@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 10**6))
def test_packed_qtensor_per_channel_roundtrip(rows, cols, seed):
    """Per-channel axis_exponents trees round-trip exactly through the
    packed container — integers in, integers out, no float detour."""
    key = jax.random.PRNGKey(seed)
    vals = jax.random.randint(key, (rows, cols), -8, 8).astype(jnp.int8)
    axis = jax.random.randint(jax.random.fold_in(key, 1), (cols,),
                              -12, 13).astype(jnp.int8)
    qt = quant.QTensor.store(vals, 3, bits=4, axis_exponents=axis)
    assert bool(jnp.array_equal(qt.int_values(), vals))
    assert bool(jnp.array_equal(qt.axis_exponents, axis))
    assert qt.stored_bytes == (rows * cols + 1) // 2 + cols
    # dequantise applies both scales (the float view, not the storage)
    want = vals.astype(jnp.float32) * 2.0**-3 * \
        jnp.exp2(-axis.astype(jnp.float32))
    assert bool(jnp.array_equal(qt.dequantize(), want))


def test_pack_po2_roundtrip_deterministic_sweep():
    """Codec round-trip without the hypothesis extra: every 4-bit value,
    odd/even/empty lengths, and a 2-D shape."""
    all_vals = jnp.arange(-8, 8, dtype=jnp.int8)
    assert bool(jnp.array_equal(
        quant.unpack_po2(quant.pack_po2(all_vals, 4), 4, (16,)), all_vals))
    rng = np.random.RandomState(0)
    for n in (0, 1, 2, 7, 27, 64):
        v = jnp.asarray(rng.randint(-8, 8, size=n), jnp.int8)
        p = quant.pack_po2(v, 4)
        assert p.size == (n + 1) // 2
        assert bool(jnp.array_equal(quant.unpack_po2(p, 4, (n,)), v))
    m = jnp.asarray(rng.randint(-8, 8, size=(5, 3)), jnp.int8)   # odd total
    assert bool(jnp.array_equal(
        quant.unpack_po2(quant.pack_po2(m, 4), 4, (5, 3)), m))


def test_pack_po2_empty_and_scalar():
    empty = jnp.zeros((0,), jnp.int8)
    assert quant.pack_po2(empty, 4).size == 0
    assert quant.unpack_po2(quant.pack_po2(empty, 4), 4, (0,)).size == 0
    one = jnp.asarray([-5], jnp.int8)
    p = quant.pack_po2(one, 4)
    assert p.size == 1
    assert int(quant.unpack_po2(p, 4, (1,))[0]) == -5


def test_qt_einsum_value_exact_vs_dequantize():
    """The integer-resident linear path returns exactly the values of the
    dequantise-first einsum (po2 unpack + de-scale are exact in f32)."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (4, 10))
    w = 0.2 * jax.random.normal(jax.random.fold_in(key, 1), (10, 6))
    for bits in (8, 4):
        qt = quant.quantize_po2(w, quant.choose_exponent(w, bits=bits),
                                bits=bits, rounding="nearest")
        got = quant.qt_einsum("bd,df->bf", x, qt)
        want = jnp.einsum("bd,df->bf", x, qt.dequantize())
        assert bool(jnp.array_equal(got, want)), bits


@given(st.integers(3, 6), st.integers(0, 10**6))
def test_quantize_act_matches_weight_quantiser_grid(y, seed):
    """The activation quantiser shares eq 9's nearest semantics with the
    PTQ weight cast: same grid, same rounding, same saturation — just in
    an f32 container instead of int8 storage."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 8)) * 2.0
    got = quant.quantize_act(x, y)
    want = quant.quantize_po2(x, y, rounding="nearest").int_values()
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want, np.float32))
    # container exactness: every value is an integer on the int8 lattice
    assert bool(jnp.array_equal(got, jnp.round(got)))


def test_quantize_act_saturation_edges():
    """Values beyond the eq-9 grid edge clamp at the bits-wide extremes;
    the half-LSB offset rounds ties toward +inf (floor(x+0.5))."""
    x = jnp.asarray([1e6, -1e6, 3.96875, -4.0, 0.015625, -0.015625, 0.0])
    q = quant.quantize_act(x, 5)                 # grid step 2^-5
    assert [int(v) for v in q] == [127, -128, 127, -128, 1, 0, 0]
    lo4, hi4 = quant.int_range(4)
    q4 = quant.quantize_act(x, 5, bits=4)
    assert int(q4.max()) == hi4 and int(q4.min()) == lo4


@given(st.integers(0, 10**6), st.booleans())
def test_int_exec_einsum_matches_int32_reference(seed, per_channel):
    """Property: the integer-executing einsum (f32-container fast path)
    is bit-equal to an explicit int32 reference — quantise, integer
    matmul, INT16 clip, per-channel po2 requant — for scalar AND
    per-channel recipes."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (5, 10))
    w = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (10, 6))
    axis = None
    if per_channel:
        axis = jnp.asarray([-1, 0, 1, 0, -2, 2], jnp.int8)
    grid = quant.quantize_po2(w, 6, rounding="nearest").int_values()
    qt = quant.QTensor.store(grid, 6, axis_exponents=axis)
    got = quant.int_exec_einsum("bd,df->bf", x, qt, x_exp=5)
    xi = quant.quantize_act(x, 5).astype(jnp.int32)
    acc = jnp.clip(xi @ qt.int_values().astype(jnp.int32),
                   quant.INT16_MIN, quant.INT16_MAX)
    want = acc.astype(jnp.float32) * jnp.float32(2.0 ** -(5 + 6))
    if axis is not None:
        want = want * jnp.exp2(-axis.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_exec_supported_matrix_and_tied_head():
    """Support matrix: weight-first always; weight-last (tied head) only
    without per-channel exponents (they'd sit on the contraction axis);
    non-QTensor / non-rank-2 never.  The supported tied-head path
    matches the int32 reference."""
    w = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (7, 10))
    qs = quant.quantize_po2(w, 6, rounding="nearest")
    qc = quant.QTensor.store(qs.int_values(), 6,
                             axis_exponents=jnp.zeros((10,), jnp.int8))
    assert quant.int_exec_supported(qs, "bsd,df->bsf")
    assert quant.int_exec_supported(qc, "bsd,df->bsf")
    assert quant.int_exec_supported(qs, "...d,vd->...v")
    assert not quant.int_exec_supported(qc, "...d,vd->...v")
    assert not quant.int_exec_supported(w, "bsd,df->bsf")
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10))
    got = quant.int_exec_einsum("bd,vd->bv", x, qs, x_exp=5)
    xi = quant.quantize_act(x, 5).astype(jnp.int32)
    acc = jnp.clip(xi @ qs.int_values().astype(jnp.int32).T,
                   quant.INT16_MIN, quant.INT16_MAX)
    want = acc.astype(jnp.float32) * jnp.float32(2.0 ** -(5 + 6))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_descale_matches_dequantized_rows():
    """Row gather + descale == gathering rows of the full dequantised
    table (exact po2 scaling commutes with the gather), int8 and packed
    int4, scalar and per-channel."""
    key = jax.random.PRNGKey(4)
    w = 0.4 * jax.random.normal(key, (12, 6))
    idx = jnp.asarray([[0, 3, 11], [5, 5, 1]])
    for bits in (8, 4):
        for axis in (None, jnp.asarray([1, 0, -1, 2, 0, -2], jnp.int8)):
            e = quant.choose_exponent(w, bits=bits)
            grid = quant.quantize_po2(w, e, bits=bits,
                                      rounding="nearest").int_values()
            qt = quant.QTensor.store(grid, e, bits=bits,
                                     axis_exponents=axis)
            got = quant.gather_descale(qt, idx)
            want = jnp.take(qt.dequantize(), idx, axis=0)
            assert bool(jnp.array_equal(got, want)), (bits, axis is None)


def test_qmatmul_matches_float():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (8, 32)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16)) * 0.1
    qx, qw = quant.quantize_po2(x, 5), quant.quantize_po2(w, 6)
    out = quant.qmatmul(qx, qw, residual_bits=32)
    np.testing.assert_allclose(np.asarray(out.dequantize()),
                               np.asarray(qx.dequantize() @ qw.dequantize()),
                               rtol=1e-5, atol=1e-5)


def test_quantize_tree_skips_norms():
    tree = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    qt = quant.quantize_tree(tree, weight_exponent=6)
    assert isinstance(qt["w"], quant.QTensor)
    assert not isinstance(qt["scale"], quant.QTensor)   # paper §IV: LN stays float
    qb, fb = quant.tree_quantized_bytes(qt)
    assert qb == 16 and fb == 16


def test_calibration_sweep_shape():
    # tiny linear model, Table V pair format
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 2))}
    batches = [(jax.random.normal(jax.random.PRNGKey(i), (16, 8)),
                jnp.zeros((16,), jnp.int32)) for i in range(2)]
    res = calibrate.sweep_scale_factors(
        lambda p, x: x @ p["w"], params, batches,
        pairs=[(3, 3), (4, 4), (5, 5), (6, 5), (6, 6)])   # = Table V rows
    assert len(res) == 5
    assert all(0.0 <= r.accuracy <= 1.0 for r in res)
