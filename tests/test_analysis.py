"""repro.analysis: the static verifier, verified.

Three layers: (1) the shipped configs x backends come back clean — the
CI analysis-gate contract; (2) each pass catches its seeded mutation
(mutation testing: a checker that cannot fail is not checking); (3) the
interval interpreter's unit-level behaviour on known pipelines.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import analysis, runtime
from repro.analysis import mutations, ranges
from repro.analysis.__main__ import main as cli_main
from repro.configs import registry
from repro.core import fixedpoint as fxp
from repro.models import kwt

CFG = registry.get("kwt-tiny").config


@pytest.fixture(scope="module")
def params():
    return kwt.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def lut_engine(params):
    return runtime.compile_model(CFG, params, backend="lut")


# ---------------------------------------------------------------------------
# clean plans pass
# ---------------------------------------------------------------------------

def test_float_plan_clean(params):
    eng = runtime.compile_model(CFG, params, backend="float")
    rep = analysis.check_engine(eng)
    assert rep.ok, rep.render()
    assert rep.result("residency").metrics["float_leak_count"] == 0
    assert rep.result("geometry").metrics["kernels"] == 0


def test_lut_plan_clean_with_no_unpack_stage(lut_engine):
    """The default lut plan integer-executes: no per-call unpack stage,
    float_leak_count == 0 — the ROADMAP full-integer criterion — and the
    plan survives the strict full-integer gate."""
    rep = analysis.check_engine(lut_engine, strict=True)
    assert rep.ok, rep.render()
    res = rep.result("residency")
    assert lut_engine.int_exec
    assert res.metrics["float_leak_count"] == 0
    assert any(f.kind == "unpack-stage" and f.severity == "info"
               for f in res.findings)
    # in-module program: every cast sanctioned, none violating
    assert res.count("violation") == 0
    # budget: the deployment plan fits the paper's 64 kB with the table
    bud = rep.result("budget").metrics
    assert bud["budget_bytes"] == 64 * 1024
    assert bud["total_bytes"] <= bud["budget_bytes"]
    assert bud["rom_bytes"] == lut_engine.rom_bytes
    # verdict lands in describe()
    assert "analysis: ok" in lut_engine.describe()


def test_non_exec_resident_plan_counts_unpack_leaks(params):
    """integer_exec=False restores the PR-5 dequantise-per-call plan:
    the separate unpack stage is back (one float cast per rank-2
    QTensor leaf, whitelisted) and the strict gate refuses it."""
    eng = runtime.compile_model(CFG, params, backend="lut",
                                integer_exec=False)
    rep = analysis.check_engine(eng, passes=("residency",))
    assert rep.ok, rep.render()
    res = rep.result("residency")
    assert res.metrics["float_leak_count"] == 9
    assert any(f.kind == "unpack-stage" and f.severity == "whitelisted"
               for f in res.findings)
    assert res.metrics["descale_sites"] > 0
    strict = analysis.check_engine(eng, passes=("residency",), strict=True)
    assert not strict.ok
    assert any(f.kind == "strict-mode"
               for f in strict.result("residency").findings)


def test_pallas_plan_clean_and_geometry(params):
    eng = runtime.compile_model(CFG, params, backend="pallas")
    rep = analysis.check_engine(eng)
    assert rep.ok, rep.render()
    geo = rep.result("geometry")
    assert geo.metrics["kernels"] >= 2          # softmax + gelu kernels
    assert 0 < geo.metrics["max_vmem_bytes"] < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# mutation testing: each pass catches its seeded violation
# ---------------------------------------------------------------------------

def test_mutation_float_leak_caught(lut_engine):
    with mutations.apply("float_leak"):
        rep = analysis.check_engine(lut_engine, passes=("residency",))
    assert not rep.ok
    assert any(f.kind == "float-leak" for f in rep.result("residency").findings)


def test_mutation_unsat_shift_caught(lut_engine):
    with mutations.apply("unsat_shift"):
        rep = analysis.check_engine(lut_engine, passes=("ranges",))
    assert not rep.ok
    assert any("overflow" in f.kind and f.severity == "violation"
               for f in rep.result("ranges").findings)


def test_mutation_big_lut_caught(lut_engine):
    with mutations.apply("big_lut"):
        rep = analysis.check_engine(lut_engine, passes=("budget",))
    assert not rep.ok
    assert any(f.kind == "ram-budget" and f.severity == "violation"
               for f in rep.result("budget").findings)


def test_mutations_restore_cleanliness(lut_engine):
    rep = analysis.check_engine(lut_engine)
    assert rep.ok, "mutation context managers must restore the originals"


# ---------------------------------------------------------------------------
# CLI exit codes (the CI gate contract)
# ---------------------------------------------------------------------------

def test_cli_clean_exits_zero(capsys):
    assert cli_main(["check", "--config", "kwt_tiny",
                     "--backend", "lut", "--passes", "residency,budget"]) == 0
    out = capsys.readouterr().out
    assert "analysis: ok" in out


@pytest.mark.parametrize("mut", mutations.MUTATIONS)
def test_cli_mutations_exit_nonzero(mut, capsys):
    assert cli_main(["check", "--config", "kwt_tiny", "--backend", "lut",
                     "--mutate", mut]) == 1
    assert "CAUGHT" in capsys.readouterr().out


def test_cli_budget_override():
    assert cli_main(["check", "--config", "kwt_tiny", "--backend", "lut",
                     "--passes", "budget", "--budget", "1024"]) == 1


# ---------------------------------------------------------------------------
# interval interpreter units
# ---------------------------------------------------------------------------

def test_interval_flags_wrapping_shift():
    def wrapping(v):
        return (fxp.to_fixed(v) << 5).astype(jnp.int32)
    f, _ = ranges.analyze_fn(wrapping, (jnp.zeros((4,)),),
                             [ranges.Interval(-8.0, 8.0)], label="t")
    assert any(f_.severity == "violation" and "overflow" in f_.kind
               for f_ in f)


def test_interval_accepts_saturating_shift():
    f, outs = ranges.analyze_fn(
        lambda v: fxp.fixed_shift_mul(fxp.to_fixed(v), 5),
        (jnp.zeros((4,)),), [ranges.Interval(-8.0, 8.0)], label="t")
    assert not any(f_.severity == "violation" for f_ in f)
    assert any(f_.kind == "guarded-overflow" for f_ in f)
    lo, hi = outs[0].lo, outs[0].hi
    assert lo >= -(2**31) and hi <= 2**31 - 1


def test_interval_fixed_mul_precondition():
    one = fxp.ONE
    clean, _ = ranges.analyze_fn(
        fxp.fixed_mul, (jnp.zeros((4,), jnp.int32),) * 2,
        [ranges.Interval(0, one), ranges.Interval(0, one)], label="t")
    assert not any(f.severity == "violation" for f in clean)
    dirty, _ = ranges.analyze_fn(
        fxp.fixed_mul, (jnp.zeros((4,), jnp.int32),) * 2,
        [ranges.Interval(0, one), ranges.Interval(0, 4 * one)], label="t")
    assert any(f.kind == "fixed-mul-precondition" for f in dirty)


def test_interval_softmax_pipeline_bounded():
    from repro.core import approx
    f, outs = ranges.analyze_fn(
        lambda v: approx.softmax(v, mode="lut_fixed"),
        (jnp.zeros((1, 27)),), [None], label="t",
        suppress_frames=("reciprocal_q24", "fixed_mul"))
    assert not any(f_.severity == "violation" for f_ in f)
    # the Q8.24 -> float exit bounds the output to the representable range
    assert outs[0].lo >= -128.0 and outs[0].hi <= 128.0
