"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
(pure-jnp oracle), interpret=True on CPU as mandated."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("shape", [(8, 32), (37, 300), (128, 128), (5, 27),
                                   (1, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_gelu_sweep(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 3).astype(dtype)
    got = ops.lut_gelu(x)
    want = ref.lut_gelu(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0, atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape", [(4, 27), (13, 99), (8, 320), (3, 1000)])
@pytest.mark.parametrize("fixed", [True, False])
def test_lut_softmax_sweep(shape, fixed):
    x = jax.random.normal(KEY, shape) * 4
    got = ops.lut_softmax(x, fixed=fixed)
    want = ref.lut_softmax(x, fixed=fixed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # sanity vs exact softmax
    assert float(jnp.max(jnp.abs(got - jax.nn.softmax(x, -1)))) < 0.05


def test_lut_softmax_fixed_bit_exact_paper_scale():
    """At the paper's SEQLEN=27 the kernel must match the Q8.24 reference
    bit-for-bit (same LUT indices, same fixed multiply)."""
    x = jax.random.normal(KEY, (16, 27)) * 3
    got = ops.lut_softmax(x, fixed=True)
    want = ref.lut_softmax(x, fixed=True)
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("mnk", [(8, 16, 32), (50, 70, 200), (128, 128, 128),
                                 (1, 5, 7)])
@pytest.mark.parametrize("residual_bits", [16, 32])
def test_int8_matmul_sweep(mnk, residual_bits):
    m, n, k = mnk
    k1, k2 = jax.random.split(KEY)
    # small magnitudes so INT16 residuals don't saturate (paper sizing)
    x = jax.random.randint(k1, (m, k), -16, 16, jnp.int8)
    w = jax.random.randint(k2, (k, n), -16, 16, jnp.int8)
    got = ops.int8_matmul(x, w, x_exp=5, w_exp=6, out_exp=7,
                          residual_bits=residual_bits)
    want = ref.int8_matmul(x, w, x_exp=5, w_exp=6, out_exp=7,
                           residual_bits=residual_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_int8_matmul_accepts_stored_qtensors():
    """The full-integer path runs the Pallas kernel directly on stored
    operands — int8 and nibble-packed int4 QTensors — reading exponents
    off the containers (the Engine's integer-resident storage form)."""
    from repro.core import quant

    k1, k2 = jax.random.split(KEY)
    x = jax.random.randint(k1, (8, 32), -16, 16, jnp.int8)
    w4 = jax.random.randint(k2, (32, 16), -8, 8, jnp.int8)
    qx = quant.QTensor(x, 5)
    qw = quant.QTensor.store(w4, 6, bits=4)           # nibble-packed
    assert qw.packed and qw.values.dtype == jnp.uint8
    got = ops.int8_matmul(qx, qw, out_exp=7)
    want = ref.int8_matmul(x, w4, x_exp=5, w_exp=6, out_exp=7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # per-channel axis exponents fold into the epilogue
    axis = jax.random.randint(jax.random.fold_in(KEY, 3), (16,),
                              -2, 3).astype(jnp.int8)
    qwc = quant.QTensor.store(w4, 6, bits=4, axis_exponents=axis)
    got_c = ops.int8_matmul(qx, qwc, out_exp=7)
    np.testing.assert_allclose(
        np.asarray(got_c),
        np.asarray(want * np.exp2(-np.asarray(axis, np.float32))),
        atol=1e-6)


def test_int8_matmul_kernel_bit_matches_jnp_int_exec_path():
    """The Engine's two int-exec flavours are the same math: the Pallas
    kernel (interpret mode) and the jnp emulation quant.int_exec_einsum
    uses on CPU agree BIT-FOR-BIT — int8 x int8, INT16 residual clip,
    po2 requant epilogue, scalar and per-channel."""
    from repro.core import quant

    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 9))
    x = jax.random.normal(k1, (8, 32))
    w = 0.3 * jax.random.normal(k2, (32, 16))
    grid = quant.quantize_po2(w, 6, rounding="nearest").int_values()
    for axis in (None, jax.random.randint(jax.random.fold_in(KEY, 10),
                                          (16,), -2, 3).astype(jnp.int8)):
        qw = quant.QTensor.store(grid, 6, axis_exponents=axis)
        jnp_out = quant.int_exec_einsum("bd,df->bf", x, qw, x_exp=5,
                                        residual_bits=16)
        xi = quant.quantize_act(x, 5).astype(jnp.int8)
        kern = ops.int8_matmul(quant.QTensor(xi, 5), qw,
                               residual_bits=16, interpret=True)
        assert jnp.array_equal(jnp_out, kern), \
            f"kernel vs jnp int-exec diverged (axis={axis is not None})"


@pytest.mark.parametrize("b,hq,hkv,lq,lk,d", [
    (1, 2, 2, 64, 64, 32),       # MHA square
    (2, 4, 2, 64, 64, 32),       # GQA
    (1, 8, 1, 128, 128, 64),     # MQA
    (2, 4, 2, 1, 64, 32),        # decode
    (1, 2, 2, 64, 256, 32),      # long kv (multi-tile online softmax)
])
@pytest.mark.parametrize("causal", [True, False])
def test_lut_attention_sweep(b, hq, hkv, lq, lk, d, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, lq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, lk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, lk, d), jnp.float32)
    exact = ops.lut_attention(q, k, v, causal=causal, use_lut=False)
    r_exact = ref.lut_attention(q, k, v, causal=causal, softmax_mode="exact")
    np.testing.assert_allclose(np.asarray(exact), np.asarray(r_exact),
                               rtol=2e-5, atol=2e-5)
    lut = ops.lut_attention(q, k, v, causal=causal, use_lut=True)
    r_lut = ref.lut_attention(q, k, v, causal=causal, softmax_mode="lut")
    # multi-tile online-LUT telescopes differently from single-shot LUT:
    # bounded by the LUT bin width (1/32) relative error per factor.
    np.testing.assert_allclose(np.asarray(lut), np.asarray(r_lut),
                               rtol=0.05, atol=0.05)
    # and must stay close to exact attention overall
    assert float(jnp.max(jnp.abs(lut - r_exact))) < 0.06


def test_lut_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 32, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 32, 32), jnp.bfloat16)
    out = ops.lut_attention(q, k, v, causal=True, use_lut=True)
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
