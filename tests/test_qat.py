"""repro.qat: STE fake-quant, QAT train step, distillation, export parity.

The headline contract (the PR's acceptance criterion): the QAT eval-path
logits are BIT-IDENTICAL to ``runtime.compile_model(cfg, exported_params,
backend="lut", recipe=exported_recipe)`` — the training loop optimises
exactly the model the Engine deploys.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import qat, runtime
from repro.checkpoint import manager
from repro.configs import registry
from repro.configs.base import ShapeSpec
from repro.core import approx
from repro.data import pipeline
from repro.launch import steps
from repro.models import kwt
from repro.optim import adamw
from repro.qat import distill as distill_mod

KEY = jax.random.PRNGKey(0)
CFG = registry.get("kwt-tiny").config
SHAPE = ShapeSpec("t", CFG.input_dim[1], 16, "train")
HP = adamw.HParams(lr=1e-3, warmup_steps=2, total_steps=50,
                   weight_decay=0.0)


@pytest.fixture(scope="module")
def params():
    return kwt.init_params(CFG, KEY)


@pytest.fixture(scope="module")
def recipe():
    return runtime.QuantRecipe.from_config(CFG)


def batch(i, b=16):
    return pipeline.keyword_batch(0, i, batch=b, input_dim=CFG.input_dim)


# ---------------------------------------------------------------------------
# fakequant: forward parity with the PTQ recipe + STE gradients
# ---------------------------------------------------------------------------

def test_fake_quant_tree_bit_identical_to_recipe_apply(params, recipe):
    fq = qat.fake_quant_tree(params, recipe)
    want = recipe.apply(params)
    for a, b in zip(jax.tree.leaves(fq), jax.tree.leaves(want)):
        assert bool(jnp.array_equal(a, b))


def test_fake_quant_per_channel_bit_identical(params, recipe):
    rc = recipe.with_(per_channel=True)
    fq = qat.fake_quant_tree(params, rc)
    want = rc.apply(params)
    for a, b in zip(jax.tree.leaves(fq), jax.tree.leaves(want)):
        assert bool(jnp.array_equal(a, b))


def test_fake_quant_skips_norms_and_biases(params, recipe):
    fq = qat.fake_quant_tree(params, recipe)
    # rank-1 leaves (biases, cls) stay float and untouched (paper §IV)
    assert fq["proj_b"] is params["proj_b"]
    assert fq["cls"] is params["cls"]
    assert not bool(jnp.array_equal(fq["proj_w"], params["proj_w"]))


def test_fake_quant_ste_gradient_is_clipped_identity(recipe):
    # values: one on-grid, one generic, one far beyond saturation
    w = jnp.asarray([[0.5, 0.3], [10.0, -10.0]])
    e = jnp.asarray(6.0)

    g = jax.grad(lambda w: jnp.sum(qat.fake_quant(w, e, recipe)))(w)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.asarray([[1.0, 1.0], [0.0, 0.0]]))


def test_exponent_gets_zero_cotangent(recipe):
    w = jnp.asarray([[0.5, 0.25]])
    ge = jax.grad(lambda e: jnp.sum(qat.fake_quant(w, e, recipe)))(
        jnp.asarray(6.0))
    assert float(ge) == 0.0


def test_calibrate_exponent_matches_choose_exponent(params, recipe):
    from repro.core import quant
    e = float(qat.calibrate_exponent(params, recipe))
    want = min(quant.choose_exponent(leaf)
               for leaf in jax.tree.leaves(params)
               if recipe._quantizes(leaf))
    assert e == float(np.clip(want, 0, 14))
    assert recipe.calibrated(params).weight_exponent == want


# ---------------------------------------------------------------------------
# approx STE: LUT modes usable (and sane) inside jax.grad
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["lut", "lut_fixed"])
def test_masked_softmax_lut_modes_have_exact_gradient(mode):
    x = 0.7 * jax.random.normal(jax.random.PRNGKey(2), (4, 9))

    g = jax.grad(lambda v: jnp.sum(
        approx.masked_softmax(v, None, mode=mode) * v))(x)
    g_exact = jax.grad(lambda v: jnp.sum(
        approx.masked_softmax(v, None, mode="exact") * v))(x)
    # backward is the exact op's vjp; forwards differ (LUT bins), so the
    # product-rule terms differ only through the forward value
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0
    assert float(jnp.max(jnp.abs(g - g_exact))) < 0.1


def test_gelu_lut_gradient_close_to_exact():
    x = jnp.linspace(-3.0, 3.0, 64)
    g = jax.grad(lambda v: jnp.sum(approx.gelu(v, mode="lut")))(x)
    ge = jax.grad(lambda v: jnp.sum(approx.gelu(v, mode="exact")))(x)
    assert bool(jnp.array_equal(g, ge))     # STE: exactly the exact vjp


def test_ste_wrapper_preserves_forward_bitwise():
    x = 0.7 * jax.random.normal(jax.random.PRNGKey(3), (8, 27))
    direct = approx.softmax_lut(x, fixed=True)
    wrapped = approx.softmax(x, mode="lut_fixed")
    assert bool(jnp.array_equal(direct, wrapped))


@pytest.mark.parametrize("mode", ["lut", "lut_fixed"])
def test_masked_softmax_ste_survives_remat_with_traced_mask(mode):
    """Regression: the mask is built inside the remat'd trace (as in
    _sdpa_block under cfg.remat) — it must flow through the STE as an
    operand, not a closure, or the bwd re-run leaks the tracer."""
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (6, 6))

    @jax.remat
    def f(v):
        mask = jnp.tril(jnp.ones((6, 6), bool))   # traced-context mask
        return jnp.sum(approx.masked_softmax(v, mask, mode=mode) * v)

    g = jax.grad(f)(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


def test_lm_qat_train_step_runs_under_remat_scan():
    """Regression: the LM QAT path (causal mask + cfg.remat + scanned
    layers + LUT softmax in the loss) crashed with an escaped-tracer
    error when the STE closed over the mask."""
    from repro.models import transformer as T

    cfg = registry.get("internlm2-1.8b").smoke
    lm_shape = ShapeSpec("t", 16, 2, "train")
    spec = qat.QATSpec(runtime.QuantRecipe.from_config(cfg))
    step = jax.jit(steps.make_train_step(cfg, lm_shape, HP, n_micro=1,
                                         qat=spec))
    p = T.init_params(cfg, KEY)
    opt = adamw.init(p, HP)
    qs = qat.init_qat_state(spec)
    b = pipeline.lm_batch(0, 0, global_batch=2, seq_len=16,
                          vocab_size=cfg.vocab_size)
    p, opt, qs, m = step(p, opt, qs, b)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(qs["step"]) == 1


# ---------------------------------------------------------------------------
# QAT train step
# ---------------------------------------------------------------------------

def _run(spec, params, n, start_qstate=None, b=16):
    step = jax.jit(steps.make_train_step(CFG, SHAPE, HP, n_micro=1,
                                         qat=spec))
    opt = adamw.init(params, HP)
    qs = start_qstate or qat.init_qat_state(spec)
    losses = []
    for i in range(n):
        params, opt, qs, m = step(params, opt, qs, batch(i, b))
        losses.append(float(m["loss"]))
    return params, opt, qs, losses


def test_qat_step_trains_and_threads_state(params, recipe):
    spec = qat.QATSpec(recipe)
    p, _, qs, losses = _run(spec, params, 30, b=64)
    assert int(qs["step"]) == 30
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_qat_delayed_start_runs_float_forward(params, recipe):
    """Before start_step the loss forward sees the raw shadow weights."""
    spec = qat.QATSpec(recipe, qat.QATConfig(start_step=1_000_000))
    qs = qat.init_qat_state(spec)
    run = qat.qat_params(params, spec, qs)
    for a, b in zip(jax.tree.leaves(run), jax.tree.leaves(params)):
        assert bool(jnp.array_equal(a, b))
    # and once past start, the fake-quant values
    qs2 = {**qs, "step": jnp.asarray(0, jnp.int32)}
    spec2 = qat.QATSpec(recipe, qat.QATConfig(start_step=0))
    run2 = qat.qat_params(params, spec2, qs2)
    want = recipe.apply(params)
    for a, b in zip(jax.tree.leaves(run2), jax.tree.leaves(want)):
        assert bool(jnp.array_equal(a, b))


def test_qat_exponent_learning_never_freezes_at_zero(params, recipe):
    """freeze_exponent_step=0 means keep recalibrating (regression: it
    used to silently disable learning entirely)."""
    spec = qat.QATSpec(recipe.with_(weight_exponent=3),
                       qat.QATConfig(learn_exponent=True))
    _, _, qs, _ = _run(spec, params, 3)
    # recalibrated away from the recipe value (the old behaviour kept 3.0
    # forever); the analytic bound for near-init weights is ~6-7
    assert float(qs["weight_exponent"]) != 3.0


def test_qat_exponent_learning_freezes(params, recipe):
    spec = qat.QATSpec(recipe.with_(weight_exponent=3),
                       qat.QATConfig(learn_exponent=True,
                                     freeze_exponent_step=3))
    _, _, qs, _ = _run(spec, params, 6)
    learned = float(qs["weight_exponent"])
    assert learned != 3.0          # recalibrated away from the recipe value
    # frozen after step 3: rerunning more steps keeps it
    spec2 = qat.QATSpec(recipe.with_(weight_exponent=3),
                        qat.QATConfig(learn_exponent=True,
                                      freeze_exponent_step=3))
    _, _, qs2, _ = _run(spec2, params, 12)
    assert float(qs2["weight_exponent"]) == learned


def test_qat_composes_with_compressed_grad_sync(params, recipe):
    from repro.dist import compress

    mesh = jax.make_mesh((1,), ("data",))
    spec = qat.QATSpec(recipe)
    step = jax.jit(steps.make_train_step(CFG, SHAPE, HP, n_micro=1,
                                         sync_mesh=mesh, qat=spec))
    p = params
    opt = adamw.init(p, HP)
    qs = qat.init_qat_state(spec)
    err = compress.init_error_state(p)
    for i in range(3):
        p, opt, qs, err, m = step(p, opt, qs, err, batch(i))
        assert bool(jnp.isfinite(m["loss"]))
    assert int(qs["step"]) == 3


# ---------------------------------------------------------------------------
# export: the acceptance criterion
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained(params):
    spec = qat.QATSpec(runtime.QuantRecipe.from_config(CFG))
    p, _, qs, _ = _run(spec, params, 20, b=64)
    return spec, p, qs


def test_qat_eval_bit_identical_to_exported_lut_engine(trained):
    # QAT eval fake-quantises weights but keeps float activations, so it
    # matches the NON-executing lut plan bitwise; the default int-exec
    # plan additionally quantises activations (eq 9) and is gated by
    # tolerance instead.
    spec, p, qs = trained
    ex = qat.export(p, spec, qs)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(7), (8, *CFG.input_dim))
    ev = qat.eval_forward(CFG, spec, ex.recipe)(p, x)
    eng = runtime.compile_model(CFG, ex.params, backend="lut",
                                recipe=ex.recipe, integer_exec=False)
    assert bool(jnp.array_equal(ev, eng.forward(x))), \
        "QAT eval path != exported lut engine"
    # the recipe equals the config default here, so the default-recipe
    # deployment path is identical too
    eng2 = runtime.compile_model(CFG, ex.params, backend="lut",
                                 integer_exec=False)
    assert bool(jnp.array_equal(ev, eng2.forward(x)))
    # the int-executing deployment of the same artifact stays within the
    # activation-quant envelope of the QAT eval logits
    eng3 = runtime.compile_model(CFG, ex.params, backend="lut")
    assert eng3.int_exec
    assert float(jnp.max(jnp.abs(ev - eng3.forward(x)))) < 0.35


def test_export_learned_exponent_round_trips(params):
    spec = qat.QATSpec(runtime.QuantRecipe.from_config(CFG),
                       qat.QATConfig(learn_exponent=True,
                                     freeze_exponent_step=2))
    p, _, qs, _ = _run(spec, params, 4)
    ex = qat.export(p, spec, qs)
    assert ex.recipe.weight_exponent == int(qs["weight_exponent"])
    # recipe JSON round-trip (the BENCH/export serialisation)
    rt = runtime.QuantRecipe.from_dict(ex.recipe.to_dict())
    assert rt == ex.recipe


def test_export_bytes_match_engine(trained):
    spec, p, qs = trained
    ex = qat.export(p, spec, qs)
    eng = runtime.compile_model(CFG, ex.params, backend="lut",
                                recipe=ex.recipe)
    assert tuple(ex.quantized_bytes) == tuple(eng.quantized_bytes)
    assert ex.quantized_bytes[0] > 0


def test_export_save_writes_artifact(trained, tmp_path):
    from repro.qat.export import save as export_save

    spec, p, qs = trained
    ex = qat.export(p, spec, qs)
    export_save(str(tmp_path / "kwt_tiny_qat"), ex)
    assert (tmp_path / "kwt_tiny_qat.npz").exists()
    import json
    meta = json.loads((tmp_path / "kwt_tiny_qat.json").read_text())
    assert meta["recipe"]["weight_exponent"] == ex.recipe.weight_exponent
    assert any(l["kind"] == "qtensor" for l in meta["leaves"])


@pytest.mark.parametrize("bits", [8, 4])
def test_export_save_load_deploys_bit_identical(params, tmp_path, bits):
    """The full artifact loop at both stored widths: quantise -> save
    packed bytes -> load -> deploy the loaded tree directly (no float
    detour) — logits bit-identical to the in-memory export, and the .npz
    payload is the packed ROM image (nibble bytes at 4-bit)."""
    import numpy as np

    from repro.qat.export import load as export_load
    from repro.qat.export import save as export_save

    recipe = runtime.QuantRecipe.from_config(CFG, bits=bits)
    if bits < 8:
        recipe = recipe.calibrated(params)
    spec = qat.QATSpec(recipe)
    ex = qat.export(params, spec, None)
    path = str(tmp_path / f"kwt_int{bits}")
    export_save(path, ex)
    lrecipe, lq = export_load(path, ex.qparams)
    assert lrecipe == ex.recipe
    for a, b in zip(jax.tree.leaves(ex.qparams), jax.tree.leaves(lq)):
        assert a.dtype == b.dtype           # stored form, no upcast
        assert bool(jnp.array_equal(a, b))
    data = np.load(path + ".npz")
    stored = sum(int(data[k].size * data[k].dtype.itemsize) for k in
                 data.files)
    assert stored == sum(ex.quantized_bytes)     # packed bytes on disk
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(11), (4, *CFG.input_dim))
    eng_mem = runtime.compile_model(CFG, ex.params, backend="lut",
                                    recipe=ex.recipe)
    eng_disk = runtime.compile_model(CFG, lq, backend="lut", recipe=lrecipe)
    assert eng_disk.int_resident
    assert bool(jnp.array_equal(eng_mem.forward(x), eng_disk.forward(x)))


# ---------------------------------------------------------------------------
# checkpoint.manager round-trip of the full QAT train state (satellite)
# ---------------------------------------------------------------------------

def test_qat_train_state_checkpoint_roundtrip_and_resume(params, recipe,
                                                         tmp_path):
    """Float shadow weights + opt moments + learned exponent + compressed
    -grad error state restore bit-exact, and training resumes on the
    exact trajectory of an uninterrupted run."""
    from repro.dist import compress

    mesh = jax.make_mesh((1,), ("data",))
    spec = qat.QATSpec(recipe, qat.QATConfig(learn_exponent=True,
                                             freeze_exponent_step=4))
    step = jax.jit(steps.make_train_step(CFG, SHAPE, HP, n_micro=1,
                                         sync_mesh=mesh,
                                         sync_per_channel=True, qat=spec))

    def advance(state, i0, n):
        p, opt, qs, err = state
        for i in range(i0, i0 + n):
            p, opt, qs, err, _ = step(p, opt, qs, err, batch(i))
        return p, opt, qs, err

    init = (params, adamw.init(params, HP), qat.init_qat_state(spec),
            compress.init_error_state(params))
    mid = advance(init, 0, 3)

    # save all four trees, restore into fresh zeros-like targets
    names = ("params", "opt", "qat", "err")
    for name, tree in zip(names, mid):
        manager.save(str(tmp_path / name), 3, tree)
    restored = tuple(
        manager.restore(str(tmp_path / name), 3,
                        jax.tree.map(jnp.zeros_like, tree))
        for name, tree in zip(names, mid))
    for a, b in zip(jax.tree.leaves(mid), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # deterministic resume: restored trajectory == uninterrupted one
    end_resumed = advance(restored, 3, 3)
    end_straight = advance(init, 0, 6)
    for a, b in zip(jax.tree.leaves(end_resumed),
                    jax.tree.leaves(end_straight)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# distillation
# ---------------------------------------------------------------------------

def _tiny_teacher():
    tcfg = distill_mod.teacher_config(
        registry.get("kwt-1").config.with_(n_layers=2), CFG)
    tparams = kwt.init_params(tcfg, jax.random.PRNGKey(9))
    return tparams, tcfg


def test_teacher_config_regrids_input():
    _, tcfg = _tiny_teacher()
    assert tcfg.input_dim == CFG.input_dim
    assert tcfg.d_model == 64 and tcfg.n_classes == 35


def test_reduce_head_shapes_and_grouping():
    tparams, tcfg = _tiny_teacher()
    red = distill_mod.reduce_head(tparams)
    assert red["head_w"].shape == (tcfg.d_model, 2)
    assert red["head_b"].shape == (2,)
    # default grouping: odd classes pool into the keyword column
    want_kw = jnp.mean(tparams["head_w"][:, 1::2], axis=-1)
    np.testing.assert_allclose(np.asarray(red["head_w"][:, 1]),
                               np.asarray(want_kw), rtol=1e-6)
    # encoder untouched
    assert red["blocks"] is tparams["blocks"]


def test_fine_grained_surrogate_coarsens_to_binary():
    """n_classes>2 batches: classes 0/1 coincide with the binary task
    (variant 0 adds no secondary ridge); binary batches are unchanged."""
    fine = pipeline.keyword_batch(3, 1, batch=512, input_dim=CFG.input_dim,
                                  n_classes=35)
    assert int(fine["labels"].max()) > 1
    binary = pipeline.keyword_batch(3, 1, batch=512,
                                    input_dim=CFG.input_dim)
    # same key derivation -> same noise/jitter draws; samples whose fine
    # label is in {0, 1} must match the binary construction for that label
    sel = np.asarray(fine["labels"] < 2)
    same = np.asarray(fine["labels"]) == np.asarray(binary["labels"])
    both = sel & same
    assert both.sum() > 0
    np.testing.assert_array_equal(np.asarray(fine["mfcc"])[both],
                                  np.asarray(binary["mfcc"])[both])


def test_distill_loss_trains_student(params, recipe):
    tparams, tcfg = _tiny_teacher()
    red = distill_mod.reduce_head(tparams)
    dspec = distill_mod.DistillSpec(red, tcfg.with_(n_classes=2),
                                    alpha=0.5, temperature=2.0)
    spec = qat.QATSpec(recipe, qat.QATConfig(), distill=dspec)
    p, _, qs, losses = _run(spec, params, 10, b=32)
    assert all(np.isfinite(losses))
    assert int(qs["step"]) == 10
    # KD gradient actually reached the student
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)))
    assert d > 0


def test_surgeon_shrink_params_keeps_highest_impact_blocks():
    from repro.tools import surgeon

    tcfg = registry.get("kwt-1").config.with_(n_layers=4,
                                              input_dim=CFG.input_dim,
                                              patch_dim=(CFG.input_dim[0], 1))
    tparams = kwt.init_params(tcfg, jax.random.PRNGKey(4))
    batches = [pipeline.keyword_batch(0, i, batch=16,
                                      input_dim=tcfg.input_dim,
                                      n_classes=tcfg.n_classes)
               for i in range(1)]
    _, scores = surgeon.ablation_scores(tparams, tcfg, batches, kwt.loss_fn)
    shrunk = surgeon.shrink_params(tparams, scores, keep=2)
    assert len(shrunk["blocks"]) == 2
    kept = [i for i, _ in scores[-2:]]
    want = [tparams["blocks"][i] for i in sorted(kept)]
    for a, b in zip(jax.tree.leaves(shrunk["blocks"]),
                    jax.tree.leaves(want)):
        assert a is b              # original order, original arrays
    # shrunk tree runs under the reduced config
    out = kwt.forward(shrunk, batches[0]["mfcc"], tcfg.with_(n_layers=2))
    assert out.shape == (16, tcfg.n_classes)
