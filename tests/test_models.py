"""Per-arch smoke tests (reduced same-family configs, one forward/train
step on CPU, output shapes + no NaNs) and decode-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import encdec as E
from repro.models import kwt as K
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _lm_batch(cfg, b=2, s=32):
    k1, k2 = jax.random.split(KEY)
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", registry.ASSIGNED)
def test_arch_smoke_forward_and_grad(arch):
    entry = registry.get(arch)
    cfg = entry.smoke
    assert cfg.family == entry.config.family    # same family as full config
    if cfg.family == "encdec":
        params = E.init_params(cfg, KEY)
        b, s = 2, 16
        batch = {"frames": jax.random.normal(KEY, (b, cfg.enc_seq, cfg.d_model)),
                 **{k: v for k, v in _lm_batch(cfg, b, s).items()}}
        logits = E.decode_train(params, E.encode(params, batch["frames"], cfg),
                                batch["tokens"], cfg)
        assert logits.shape == (b, s, cfg.padded_vocab)
        loss, grads = jax.value_and_grad(E.loss_fn)(params, batch, cfg)
    else:
        params = T.init_params(cfg, KEY)
        batch = _lm_batch(cfg)
        logits = T.forward(params, batch["tokens"], cfg)
        assert logits.shape == (2, 32, cfg.padded_vocab)
        loss, grads = jax.value_and_grad(T.loss_fn)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2.5-14b",
                                  "granite-moe-3b-a800m", "deepseek-moe-16b",
                                  "rwkv6-3b", "chameleon-34b",
                                  "internlm2-1.8b", "nemotron-4-340b"])
def test_decode_matches_forward(arch):
    cfg = registry.get(arch).smoke
    if cfg.family == "moe":
        # exact decode==forward equivalence requires drop-free routing
        # (capacity drops are T-dependent; GShard semantics, DESIGN.md §8)
        cfg = cfg.with_(capacity_factor=8.0)
    params = T.init_params(cfg, KEY)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    ref = T.forward(params, toks, cfg)[:, -1]
    state = T.init_decode_state(cfg, b, max_len=32)
    _, state = T.prefill(params, toks[:, :-1], cfg, state)
    lg, _ = T.decode_step(params, toks[:, -1], cfg, state)
    rel = float(jnp.max(jnp.abs(lg - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 1e-4


def test_hymba_ring_decode_matches_forward():
    """Token-by-token ring decode (incl. window wraparound) == forward."""
    cfg = registry.get("hymba-1.5b").smoke         # window 8
    params = T.init_params(cfg, KEY)
    b, n = 2, 20
    toks = jax.random.randint(KEY, (b, n), 0, cfg.vocab_size)
    state = T.init_decode_state(cfg, b, max_len=64)
    outs = []
    for t in range(n):
        lg, state = T.decode_step(params, toks[:, t], cfg, state)
        outs.append(lg)
    ref = T.forward(params, toks, cfg)
    rel = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - ref))) \
        / float(jnp.max(jnp.abs(ref)))
    assert rel < 1e-4


def test_whisper_decode_matches_forward():
    cfg = registry.get("whisper-large-v3").smoke
    params = E.init_params(cfg, KEY)
    b, s = 2, 8
    frames = jax.random.normal(KEY, (b, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    ref = E.decode_train(params, E.encode(params, frames, cfg), toks, cfg)[:, -1]
    state = E.init_decode_state(cfg, b, max_len=16)
    _, state = E.prefill(params, frames, toks[:, :-1], cfg, state)
    lg, _ = E.decode_step(params, toks[:, -1], cfg, state)
    assert float(jnp.max(jnp.abs(lg - ref))) < 1e-3


# --- recurrence oracles ----------------------------------------------------

def test_rwkv_chunked_matches_naive():
    b, h, s, dh = 2, 3, 67, 16     # non-multiple length exercises the tail
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, h, s, dh))
    k = jax.random.normal(ks[1], (b, h, s, dh))
    v = jax.random.normal(ks[2], (b, h, s, dh))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, dh)))
    u = jax.random.normal(ks[4], (h, dh)) * 0.1
    S0 = jnp.zeros((b, h, dh, dh))
    y1, s1 = R.wkv_naive(r, k, v, lw, u, S0)
    y2, s2 = R.wkv_scan(r, k, v, lw, u, S0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_mamba_chunked_matches_naive():
    b, s, d, n = 2, 53, 8, 4
    ks = jax.random.split(KEY, 5)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)))
    xin = jax.random.normal(ks[1], (b, s, d))
    bt = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    A = -jnp.exp(jax.random.normal(ks[4], (d, n)))
    h0 = jnp.zeros((b, d, n))
    la = delta[..., None] * A[None, None]
    dbx = (delta * xin)[..., None] * bt[:, :, None, :]
    y1, h1 = S.ssm_naive(la, dbx, C, h0)
    y2, h2 = S.ssm_scan(delta, xin, bt, C, A, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_state_continuity():
    """prefill(a+b) == prefill(a) then prefill(b) via carried state."""
    cfg = registry.get("rwkv6-3b").smoke
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 24), 0, cfg.vocab_size)
    s_full = T.init_decode_state(cfg, 1, 24)
    ref, _ = T.prefill(params, toks, cfg, s_full)
    st = T.init_decode_state(cfg, 1, 24)
    _, st = T.prefill(params, toks[:, :11], cfg, st)
    lg, _ = T.prefill(params, toks[:, 11:], cfg, st)
    assert float(jnp.max(jnp.abs(lg - ref))) < 1e-3


# --- KWT (the paper's model) -----------------------------------------------

def test_kwt_tiny_param_count_matches_paper():
    cfg = registry.get("kwt-tiny").config
    params = K.init_params(cfg, KEY)
    assert K.count_params(params) == 1646          # Table IV, exactly


def test_kwt_1_param_count_close_to_paper():
    cfg = registry.get("kwt-1").config
    params = K.init_params(cfg, KEY)
    n = K.count_params(params)
    assert abs(n - 607_000) / 607_000 < 0.02       # Table I: 607k


def test_kwt_forward_shapes():
    for name in ("kwt-tiny", "kwt-1"):
        cfg = registry.get(name).config
        params = K.init_params(cfg, KEY)
        x = jax.random.normal(KEY, (4, cfg.input_dim[0], cfg.input_dim[1]))
        logits = K.forward(params, x, cfg)
        assert logits.shape == (4, cfg.n_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))
