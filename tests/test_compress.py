"""Single-device coverage of the repro.dist.compress math: the int8
quantise/dequantise round trip, the error-state pytree contract, and the
error-feedback conservation identity — no 8-device subprocess harness
needed (that lives in test_dist.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.dist import compress

KEY = jax.random.PRNGKey(0)


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(KEY, (128, 64)) * 0.3
    q, scale = compress.quantize_leaf(g)
    assert q.dtype == jnp.int8
    assert scale.shape == ()
    back = compress.dequantize_leaf(q, scale)
    # round-to-nearest: absolute error <= scale/2 = max|g| / 254
    max_err = float(jnp.max(jnp.abs(back - g)))
    assert max_err <= 0.5 * float(scale) + 1e-7
    rel = max_err / float(jnp.max(jnp.abs(g)))
    assert rel < 1.0 / 253.0


def test_quantize_saturates_at_127():
    g = jnp.asarray([-10.0, 0.0, 10.0])
    q, scale = compress.quantize_leaf(g)
    assert int(jnp.max(q.astype(jnp.int32))) == 127
    assert int(jnp.min(q.astype(jnp.int32))) == -127
    assert float(scale) == pytest.approx(10.0 / 127.0)


def test_error_state_pytree_structure():
    grads = {"w": jnp.ones((3, 2), jnp.bfloat16),
             "blocks": {"b": jnp.zeros((5,)), "c": jnp.ones((2, 2, 2))}}
    err = compress.init_error_state(grads)
    assert jax.tree.structure(err) == jax.tree.structure(grads)
    for g, e in zip(jax.tree.leaves(grads), jax.tree.leaves(err)):
        assert e.shape == g.shape
        assert e.dtype == jnp.float32          # residuals accumulate in f32
        assert float(jnp.max(jnp.abs(e))) == 0.0


def test_sync_conservation_single_device():
    """synced + new_err == grads + err exactly (nothing lost, only moved):
    the telescoping identity the 16-step drift bound relies on."""
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(KEY, (32, 16)) * 0.1}
    err = compress.init_error_state(grads)
    synced, err1 = compress.compressed_grad_sync(grads, err, mesh)
    assert float(jnp.max(jnp.abs(
        synced["w"] + err1["w"] - grads["w"]))) < 1e-7
    # second step: residual-corrected, still conservative
    g2 = {"w": grads["w"] * 1.7}
    synced2, err2 = compress.compressed_grad_sync(g2, err1, mesh)
    assert float(jnp.max(jnp.abs(
        synced2["w"] + err2["w"] - (g2["w"] + err1["w"])))) < 1e-7


def test_sync_relative_error_bound_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(KEY, (64, 64))}
    synced, _ = compress.compressed_grad_sync(
        grads, compress.init_error_state(grads), mesh)
    rel = float(jnp.max(jnp.abs(synced["w"] - grads["w"]))) \
        / float(jnp.max(jnp.abs(grads["w"])))
    assert rel < 0.02


def test_reduce_axis_prefers_pod():
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    assert compress.reduce_axis(mesh) == "pod"
    assert compress.reduce_axis(jax.make_mesh((1,), ("data",))) == "data"


# --- per-channel payload scales (PR 1 follow-up) ---------------------------

def test_per_channel_scale_shapes():
    g = jax.random.normal(KEY, (8, 16, 4))
    q, scale = compress.quantize_leaf(g, per_channel=True)
    assert q.dtype == jnp.int8 and q.shape == g.shape
    assert scale.shape == (8,)                    # one scale per channel
    # rank-1 leaves fall back to the per-tensor scalar
    _, s1 = compress.quantize_leaf(jnp.ones((5,)), per_channel=True)
    assert s1.shape == ()


def test_per_channel_beats_per_tensor_on_heterogeneous_rows():
    """Rows spanning orders of magnitude: a per-tensor scale crushes the
    small rows (the motivation for the option)."""
    rows = jnp.stack([jnp.ones((64,)) * 1e-3,
                      jax.random.normal(KEY, (64,))])
    for per_channel in (False, True):
        q, s = compress.quantize_leaf(rows, per_channel=per_channel)
        back = compress.dequantize_leaf(q, s)
        rel = float(jnp.max(jnp.abs(back[0] - rows[0]))) / 1e-3
        if per_channel:
            assert rel < 1.0 / 100.0              # small row keeps 8 bits
        else:
            assert rel > 1.0 / 100.0              # crushed by the big row


def test_per_channel_sync_conservation():
    """The error-feedback conservation identity must hold with per-channel
    scales too: synced + new_err == grads + err exactly."""
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(KEY, (16, 32)) * 0.1,
             "b": jax.random.normal(KEY, (32,))}
    err = compress.init_error_state(grads)
    synced, err1 = compress.compressed_grad_sync(grads, err, mesh,
                                                 per_channel=True)
    for k in grads:
        assert float(jnp.max(jnp.abs(
            synced[k] + err1[k] - grads[k]))) < 1e-7


# --- int4 wire payloads through the shared core.quant codec ----------------

def test_int4_payload_packs_and_roundtrips():
    """bits=4 payloads are nibble-packed uint8 (HALF the int8 wire bytes,
    odd sizes padded) and invert exactly through the shared codec."""
    g = jax.random.normal(KEY, (31, 3)) * 0.2         # odd element count
    q8, _ = compress.quantize_leaf(g)
    q4, s4 = compress.quantize_leaf(g, bits=4)
    assert q8.dtype == jnp.int8 and q8.size == g.size
    assert q4.dtype == jnp.uint8 and q4.size == (g.size + 1) // 2
    back = compress.dequantize_leaf(q4, s4, bits=4, shape=g.shape)
    assert back.shape == g.shape
    # round-to-nearest at 4 bits: error <= scale/2 = max|g| / 14
    assert float(jnp.max(jnp.abs(back - g))) <= 0.5 * float(s4) + 1e-7


def test_int4_sync_conservation_and_error_bound():
    """The conservation identity is payload-width-independent; the one-step
    relative error grows to the 4-bit bound but no further."""
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(KEY, (33, 17)) * 0.3,   # odd sizes
             "b": jax.random.normal(KEY, (7,))}
    err = compress.init_error_state(grads)
    synced, err1 = compress.compressed_grad_sync(grads, err, mesh, bits=4)
    for k in grads:
        assert float(jnp.max(jnp.abs(
            synced[k] + err1[k] - grads[k]))) < 1e-7
        rel = float(jnp.max(jnp.abs(synced[k] - grads[k]))) \
            / float(jnp.max(jnp.abs(grads[k])))
        assert rel <= 0.5 / 7 + 1e-6                   # half-LSB of ±7 grid
    # per-channel composes with the packed payload
    synced_c, err_c = compress.compressed_grad_sync(
        grads, err, mesh, per_channel=True, bits=4)
    for k in grads:
        assert float(jnp.max(jnp.abs(
            synced_c[k] + err_c[k] - grads[k]))) < 1e-7
