"""repro.runtime: backend registry, QuantRecipe, Engine contracts.

The Engine-level restatement of the PR-2 guarantee: for ANY backend,
streaming logits are bit-identical to the same engine's offline forward;
across backends, float / lut / pallas logits agree within the documented
PTQ + LUT-bin tolerance, and the pallas (interpret) path is bit-identical
to the jnp Q8.24 LUT reference on KWT (mask-free attention takes the raw
kernel path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime, telemetry
from repro.configs import registry
from repro.core import quant
from repro.kernels import ops
from repro.models import kwt
from repro.stream import engine as stream_engine
from repro.stream import features

KEY = jax.random.PRNGKey(0)
CFG = registry.get("kwt-tiny").config
FCFG = features.FrontendConfig()
HOP = FCFG.hop_len
T = CFG.input_dim[1]

# |float - lut| logit bound on KWT-Tiny: Table V PTQ (w 2^6 -> LSB 2^-6
# per weight) + 1/32 LUT bin width through one block.  Measured ~0.11 at
# init scale; 0.35 guards regression without overfitting the seed.
FLOAT_VS_LUT_TOL = 0.35


@pytest.fixture(scope="module")
def params():
    return kwt.init_params(CFG, KEY)


@pytest.fixture(scope="module")
def mfcc():
    return 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                   (4, *CFG.input_dim))


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_registry_has_the_backend_matrix():
    names = runtime.available_backends()
    for expected in ("float", "lut_float", "lut", "pallas"):
        assert expected in names


def test_unknown_backend_raises_with_choices():
    with pytest.raises(KeyError, match="float"):
        runtime.get_backend("tpu_v7")


def test_configure_pins_modes_once():
    f = runtime.get_backend("float").configure(CFG)
    assert (f.softmax_mode, f.act_approx) == ("exact", "exact")
    l = runtime.get_backend("lut").configure(CFG)
    assert (l.softmax_mode, l.act_approx) == ("lut_fixed", "lut")
    p = runtime.get_backend("pallas").configure(CFG)
    assert (p.softmax_mode, p.act_approx) == ("pallas", "pallas")
    # the interpret/Mosaic decision is made here, at plan time (CPU -> True)
    assert p.kernel_interpret is runtime.plan_interpret() is True


# ---------------------------------------------------------------------------
# Engine: offline forward
# ---------------------------------------------------------------------------

def test_float_engine_matches_raw_forward_bitwise(params, mfcc):
    eng = runtime.compile_model(CFG, params, backend="float")
    ref = jax.jit(lambda p, x: kwt.forward(p, x, CFG))(params, mfcc)
    assert bool(jnp.array_equal(eng.forward(mfcc), ref))


def test_three_backend_parity(params, mfcc):
    """The acceptance criterion: float vs lut vs pallas logits agree
    within the documented tolerance, and pallas == lut bit-for-bit."""
    out = {b: runtime.compile_model(CFG, params, backend=b).forward(mfcc)
           for b in ("float", "lut", "pallas")}
    d = float(jnp.max(jnp.abs(out["float"] - out["lut"])))
    assert d < FLOAT_VS_LUT_TOL, f"float vs lut drifted: {d}"
    # KWT attention is mask-free -> the pallas mode is the raw kernel,
    # whose Q8.24 pipeline matches the jnp reference exactly (int32 sums
    # are order-independent).
    assert bool(jnp.array_equal(out["lut"], out["pallas"])), (
        "pallas kernel diverged from the Q8.24 reference (max diff "
        f"{float(jnp.max(jnp.abs(out['lut'] - out['pallas'])))})")


def test_embed_encode_compose_to_forward(params, mfcc):
    eng = runtime.compile_model(CFG, params, backend="lut")
    logits = eng.encode_window(eng.embed_frames(jnp.swapaxes(mfcc, 1, 2)))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(eng.forward(mfcc)),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Engine: streaming bit-identity (the PR-2 contract, restated per backend)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["float", "lut", "pallas"])
def test_engine_streaming_bit_identical_to_offline(params, backend):
    hops = T + 6
    audio = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (2, hops * HOP))
    eng = runtime.compile_model(CFG, params, backend=backend)
    state = stream_engine.init_stream_state(eng.exec_cfg, FCFG, 2)
    logits = None
    for i in range(0, hops * HOP, HOP):
        state, logits = eng.stream_step(state, audio[:, i:i + HOP], FCFG)
    assert bool(stream_engine.warm(state).all())
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(audio)[..., hops - T:]
    ref = eng.forward(off)
    assert bool(jnp.array_equal(logits, ref)), \
        f"streaming != offline under backend={backend}"


# ---------------------------------------------------------------------------
# QuantRecipe
# ---------------------------------------------------------------------------

def test_recipe_subsumes_quantize_params(params):
    want = quant.dequantize_tree(
        quant.quantize_tree(params, weight_exponent=6, rounding="nearest"))
    got = runtime.QuantRecipe.from_config(CFG).apply(params)
    shim = runtime.quantize_params(params, CFG)
    for a, b, c in zip(jax.tree.leaves(want), jax.tree.leaves(got),
                       jax.tree.leaves(shim)):
        assert bool(jnp.array_equal(a, b))
        assert bool(jnp.array_equal(a, c))


def test_recipe_from_config_reads_quant_config():
    r = runtime.QuantRecipe.from_config(CFG)
    assert (r.weight_exponent, r.input_exponent, r.residual_bits) == (6, 5, 16)
    r2 = runtime.QuantRecipe.from_config(CFG, weight_exponent=4)
    assert r2.weight_exponent == 4


def test_recipe_per_channel_registry_defaults():
    """PR-3 follow-up: LM-scale configs default to per-channel refinement;
    KWT configs keep the paper's scalar Table V recipe (regression)."""
    kwt_r = runtime.QuantRecipe.from_config(CFG)
    assert kwt_r.per_channel is False
    assert (kwt_r.weight_exponent, kwt_r.input_exponent) == (6, 5)
    lm = registry.get("internlm2-1.8b").smoke
    assert runtime.QuantRecipe.from_config(lm).per_channel is True
    # an explicit QuantConfig.per_channel wins over the family default
    lm_off = lm.with_(quant=registry.get("kwt-tiny").config.quant.__class__(
        per_channel=False))
    assert runtime.QuantRecipe.from_config(lm_off).per_channel is False
    kwt_on = CFG.with_(quant=CFG.quant.__class__(per_channel=True))
    assert runtime.QuantRecipe.from_config(kwt_on).per_channel is True


def test_recipe_per_channel_reduces_error():
    # channels spanning very different magnitudes: one global power-of-2
    # scale wastes resolution on the small channels
    k1, k2 = jax.random.split(KEY)
    w = jnp.concatenate([
        0.9 * jax.random.normal(k1, (32, 4)),
        0.01 * jax.random.normal(k2, (32, 4))], axis=1)
    tree = {"w": w}
    err_g = jnp.max(jnp.abs(
        runtime.QuantRecipe(per_channel=False).apply(tree)["w"] - w))
    err_c = jnp.max(jnp.abs(
        runtime.QuantRecipe(per_channel=True).apply(tree)["w"] - w))
    assert float(err_c) < float(err_g)


def test_recipe_floor_matches_paper_cast():
    tree = {"w": jax.random.normal(KEY, (8, 8))}
    got = runtime.QuantRecipe(rounding="floor").apply(tree)["w"]
    want = quant.dequantize_tree(
        quant.quantize_tree(tree, weight_exponent=6, rounding="floor"))["w"]
    assert bool(jnp.array_equal(got, want))


def test_explicit_recipe_forces_ptq_on_float_backend(params, mfcc):
    """Table IX middle column: quantised weights, exact float ops."""
    eng = runtime.compile_model(
        CFG, params, backend="float",
        recipe=runtime.QuantRecipe.from_config(CFG))
    assert eng.quantized_bytes is not None and eng.quantized_bytes[0] > 0
    assert eng.exec_cfg.softmax_mode == "exact"
    # params actually changed (PTQ round trip)
    assert not bool(jnp.array_equal(eng.params["proj_w"], params["proj_w"]))
    assert bool(jnp.all(jnp.isfinite(eng.forward(mfcc))))


# ---------------------------------------------------------------------------
# Engine introspection / guards
# ---------------------------------------------------------------------------

def test_engine_introspection(params):
    f = runtime.compile_model(CFG, params, backend="float")
    l = runtime.compile_model(CFG, params, backend="lut")
    p = runtime.compile_model(CFG, params, backend="pallas")
    # rom_bytes is now the TRUE packed weight image (1646 params = the
    # paper's 1.65 kB; the 146 rank-1 leaves stay float per §IV -> 1500 B
    # of int8 ROM); the LUT bank moved to lut_bytes (paper: 2.69 kB).
    assert (f.rom_bytes, l.rom_bytes, p.rom_bytes) == (0, 1500, 1500)
    assert (f.lut_bytes, l.lut_bytes, p.lut_bytes) == (0, 2688, 2688)
    assert f.interpret is None and l.interpret is None and p.interpret is True
    assert l.param_bytes < f.param_bytes        # int8 weights + float norms
    assert "lut" in l.describe() and "interpret" in p.describe()
    assert f.backend_name == "float"


# ---------------------------------------------------------------------------
# integer-resident QTensors (the storage-contract acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["lut", "pallas"])
def test_integer_resident_bit_identical_to_dequant_first(params, mfcc,
                                                         backend):
    """Non-executing resident plans (integer_exec=False) keep the PR-5
    contract: logits BIT-IDENTICAL to the dequantise-first float-weight
    path (po2 epilogue scaling is exact and commutes with the
    reduction).  The default int-executing plan quantises activations
    (eq 9) as part of its math, so it is checked against the Q8.24-
    family tolerance instead (see the int-exec tests below)."""
    resident = runtime.compile_model(CFG, params, backend=backend,
                                     integer_exec=False)
    dequant = runtime.compile_model(CFG, params, backend=backend,
                                    integer_resident=False,
                                    integer_exec=False)
    assert resident.int_resident and not dequant.int_resident
    assert not resident.int_exec
    assert isinstance(resident.params["proj_w"], quant.QTensor)
    assert bool(jnp.array_equal(resident.forward(mfcc),
                                dequant.forward(mfcc))), backend


def test_integer_resident_int4_bit_identical_and_packed(params, mfcc):
    """4-bit recipe: weights live nibble-packed inside the Engine, logits
    still bit-identical to the dequant-first path under the same recipe
    (both plans non-executing)."""
    r4 = runtime.QuantRecipe.from_config(CFG, bits=4).calibrated(params)
    resident = runtime.compile_model(CFG, params, backend="lut", recipe=r4,
                                     integer_exec=False)
    dequant = runtime.compile_model(CFG, params, backend="lut", recipe=r4,
                                    integer_resident=False,
                                    integer_exec=False)
    w = resident.params["proj_w"]
    assert isinstance(w, quant.QTensor) and w.packed
    assert w.values.dtype == jnp.uint8 and w.shape == (16, 12)
    assert w.values.size == (16 * 12 + 1) // 2
    assert bool(jnp.array_equal(resident.forward(mfcc), dequant.forward(mfcc)))


def test_rom_bytes_match_paper_and_halve_at_int4(params):
    """Acceptance: kwt-tiny packed ROM ~ the paper's 1.65 kB at 8-bit
    (1646 params; our 146 rank-1 leaves stay float per §IV -> 1500 B of
    weight ROM) and halves (±nibble padding) at 4-bit."""
    e8 = runtime.compile_model(CFG, params, backend="lut")
    assert e8.rom_bytes == 1500
    paper_rom = 1646                       # 1.65 kB: every param at 1 byte
    assert abs(e8.rom_bytes + 146 - paper_rom) <= 2   # exact modulo rank-1
    r4 = runtime.QuantRecipe.from_config(CFG, bits=4).calibrated(params)
    e4 = runtime.compile_model(CFG, params, backend="lut", recipe=r4)
    n_leaves = 9                            # quantised rank>=2 leaves
    assert e8.rom_bytes // 2 <= e4.rom_bytes <= e8.rom_bytes // 2 + n_leaves
    assert e4.param_bytes < e8.param_bytes


@pytest.mark.parametrize("backend", ["lut", "pallas"])
def test_integer_resident_streaming_still_bit_identical(params, backend):
    """The PR-2 streaming contract survives integer residency: packed
    weights inside stream_step produce the same logits as offline."""
    hops = T + 3
    audio = 0.1 * jax.random.normal(jax.random.PRNGKey(7), (2, hops * HOP))
    r4 = runtime.QuantRecipe.from_config(CFG, bits=4).calibrated(params)
    eng = runtime.compile_model(CFG, params, backend=backend, recipe=r4)
    assert eng.int_resident
    state = stream_engine.init_stream_state(eng.exec_cfg, FCFG, 2)
    logits = None
    for i in range(0, hops * HOP, HOP):
        state, logits = eng.stream_step(state, audio[:, i:i + HOP], FCFG)
    off = jax.jit(lambda a: features.mfcc(a, FCFG))(audio)[..., hops - T:]
    assert bool(jnp.array_equal(logits, eng.forward(off)))


# ---------------------------------------------------------------------------
# full-integer execution (int8 x int8 on the stored payload, no unpack)
# ---------------------------------------------------------------------------

def test_default_quantised_backends_are_int_executing(params, mfcc):
    """The lut/pallas defaults now EXECUTE on the stored integer payload:
    int_exec pins on, describe() says so, and both flavours agree
    bit-for-bit (same integer math, kernel vs jnp emulation)."""
    f = runtime.compile_model(CFG, params, backend="float")
    l = runtime.compile_model(CFG, params, backend="lut")
    p = runtime.compile_model(CFG, params, backend="pallas")
    assert not f.int_exec and l.int_exec and p.int_exec
    assert "int-exec" in l.describe() and "int-exec" in p.describe()
    # the execution path still consumes the packed QTensor directly
    assert isinstance(l.params["proj_w"], quant.QTensor)
    assert bool(jnp.array_equal(l.forward(mfcc), p.forward(mfcc)))


# max-abs logit drift of the int-executing plan vs float grows with the
# number of samples maxed over (extreme-value: each adds a fresh draw of
# the eq-9 activation-rounding noise).  Measured 0.27 / 0.42 / 0.62 at
# batch 1 / 8 / 64 on the init-scale seed; 0.8 guards regression.
INT_EXEC_BATCH_TOL = 0.8
# int4 weights carry 4x the weight-grid LSB on top of the activation
# envelope; measured 0.81 at init scale.
INT_EXEC_INT4_TOL = 1.2


@pytest.mark.parametrize("batch", [1, 8, 64])
def test_int_exec_parity_across_batches(params, batch):
    """Int-exec logits are per-sample deterministic (batch size cannot
    change any sample's integer math) and stay inside the pinned
    envelope vs float at every serving batch, including the bench's
    batch 64."""
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(11),
                                (64, *CFG.input_dim))
    lut = runtime.compile_model(CFG, params, backend="lut")
    flt = runtime.compile_model(CFG, params, backend="float")
    xb = x[:batch]
    out = lut.forward(xb)
    assert bool(jnp.array_equal(out, lut.forward(x)[:batch]))
    d = float(jnp.max(jnp.abs(out - flt.forward(xb))))
    assert d < INT_EXEC_BATCH_TOL, f"batch={batch} drifted: {d}"


def test_int_exec_plan_emits_no_unpack_span(params, mfcc):
    """The unpack stage is GONE for int-executing plans — not merely
    cheap: the traced forward has no ``unpack`` span at all, while a
    non-executing resident plan still shows one."""
    lut = runtime.compile_model(CFG, params, backend="lut")
    with telemetry.tracing() as tr:
        lut.forward(mfcc)
    assert len(tr.durations_us("unpack")) == 0
    assert len(tr.durations_us("forward")) == 1
    resident = runtime.compile_model(CFG, params, backend="lut",
                                     integer_exec=False)
    with telemetry.tracing() as tr2:
        resident.forward(mfcc)
    assert len(tr2.durations_us("unpack")) == 1


def test_int_exec_int4_nibble_path(params, mfcc):
    """int4 recipes integer-execute off the nibble-packed payload: the
    plan stays packed (uint8 storage), pins int_exec, and matches its
    non-executing twin within the quantised-activation envelope."""
    r4 = runtime.QuantRecipe.from_config(CFG, bits=4).calibrated(params)
    eng = runtime.compile_model(CFG, params, backend="lut", recipe=r4)
    assert eng.int_exec and eng.params["proj_w"].packed
    ref = runtime.compile_model(CFG, params, backend="lut", recipe=r4,
                                integer_exec=False)
    d = float(jnp.max(jnp.abs(eng.forward(mfcc) - ref.forward(mfcc))))
    assert d < INT_EXEC_INT4_TOL, f"int4 int-exec drifted: {d}"


def test_compile_model_accepts_prequantized_tree(params, mfcc):
    """A packed QTensor tree (e.g. a QAT export artifact) deploys as-is:
    no float detour, no re-quantisation, same logits."""
    recipe = runtime.QuantRecipe.from_config(CFG)
    qtree = recipe.quantize(params)
    from_float = runtime.compile_model(CFG, params, backend="lut",
                                       recipe=recipe)
    from_packed = runtime.compile_model(CFG, qtree, backend="lut")
    assert from_packed.quantized_bytes == from_float.quantized_bytes
    assert bool(jnp.array_equal(from_packed.forward(mfcc),
                                from_float.forward(mfcc)))


def test_lm_engine_rejects_kwt_entry_points():
    cfg = registry.get("internlm2-1.8b").smoke
    from repro.models import transformer as Tmod
    lm = runtime.compile_model(cfg, Tmod.init_params(cfg, KEY),
                               backend="float")
    with pytest.raises(NotImplementedError, match="embed_frames"):
        lm.embed_frames(jnp.zeros((1, 2, 3)))


# ---------------------------------------------------------------------------
# flash-LUT attention through the Backend registry (attention= knob)
# ---------------------------------------------------------------------------

def test_attention_knob_pins_attn_impl(params):
    eng = runtime.compile_model(CFG, params, backend="lut_float",
                                attention="flash_lut")
    assert eng.exec_cfg.attn_impl == "flash_lut"
    assert eng.interpret is True          # kernel decision made at plan time
    assert "flash_lut" in eng.describe()
    # default stays the XLA sdpa path
    assert runtime.compile_model(CFG, params,
                                 backend="lut").exec_cfg.attn_impl == "xla"
    with pytest.raises(ValueError, match="flash_lut"):
        runtime.compile_model(CFG, params, attention="tpu_v7")


def test_flash_lut_layers_path_matches_direct_ops_call(params):
    """Parity with the direct kernels.ops.lut_attention path: the routed
    attention layer is the kernel verbatim (bit-identical)."""
    from repro.models import layers as L

    eng = runtime.compile_model(CFG, params, backend="lut_float",
                                attention="flash_lut")
    cfg, p = eng.exec_cfg, eng.params
    bp = p["blocks"][0]["attn"]
    emb = kwt.embed_frames(p, jnp.swapaxes(
        0.5 * jax.random.normal(jax.random.PRNGKey(11),
                                (2, *CFG.input_dim)), 1, 2), cfg)
    b = emb.shape[0]
    cls = jnp.broadcast_to(p["cls"], (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, emb], axis=1) + p["pos"]
    routed, _ = L.apply_attention(bp, x, cfg,
                                  positions=jnp.arange(x.shape[1]),
                                  causal=False)
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    q = (jnp.einsum("bsd,df->bsf", x, bp["wq"]) + bp["bq"]).reshape(
        b, -1, h, dh)
    k = (jnp.einsum("bsd,df->bsf", x, bp["wk"]) + bp["bk"]).reshape(
        b, -1, h, dh)
    v = (jnp.einsum("bsd,df->bsf", x, bp["wv"]) + bp["bv"]).reshape(
        b, -1, h, dh)
    o = ops.lut_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=False,
                          interpret=True)
    direct = jnp.einsum("bsf,fd->bsd",
                        jnp.swapaxes(o, 1, 2).reshape(b, -1, h * dh),
                        bp["wo"]) + bp["bo"]
    assert bool(jnp.array_equal(routed, direct.astype(routed.dtype)))


def test_flash_lut_engine_close_to_sdpa_lut(params, mfcc):
    """Whole-model sanity: online-softmax (flash) vs the jnp float-LUT
    softmax differ only in rescale order — logits stay within a tight
    tolerance of the sdpa lut_float engine."""
    flash = runtime.compile_model(CFG, params, backend="lut_float",
                                  attention="flash_lut").forward(mfcc)
    sdpa = runtime.compile_model(CFG, params,
                                 backend="lut_float").forward(mfcc)
    assert float(jnp.max(jnp.abs(flash - sdpa))) < 1e-4


# ---------------------------------------------------------------------------
# kernels.ops shared block-geometry helpers
# ---------------------------------------------------------------------------

def test_fit_block_divides_and_respects_preferred():
    assert ops.fit_block(1792, 1024) == 256
    assert ops.fit_block(300, 128) == 4
    assert ops.fit_block(27, 128) == 27
    assert ops.fit_block(8, 128) == 8
    assert ops.fit_block(7, 8) == 7
    for size in (1, 5, 27, 96, 300, 1792):
        for pref in (1, 8, 128, 1024):
            b = ops.fit_block(size, pref)
            assert 1 <= b <= max(pref, 1) + size and size % b == 0
            assert b <= size


def test_pad_to_block_pads_and_reports_size():
    x = jnp.ones((5, 27))
    p, m0 = ops.pad_to_block(x, 0, 8)
    assert p.shape == (8, 27) and m0 == 5
    assert float(p[5:].sum()) == 0.0              # pad value
    p2, n0 = ops.pad_to_block(x, 1, 128, value=-1.0)
    assert p2.shape == (5, 128) and n0 == 27
    assert float(p2[:, 27:].max()) == -1.0
    same, s0 = ops.pad_to_block(x, 0, 5)
    assert same is x and s0 == 5                  # no-op when aligned
