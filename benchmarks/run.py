"""Benchmark harness: one function per paper table (benchmarks.paper_tables)
plus kernel micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV.

``--backend-sweep`` times one KWT-Tiny forward per runtime backend
(float / lut_float / lut / pallas-interpret) through the same
``runtime.compile_model`` Engine the launchers serve with, and emits
``BENCH_runtime.json`` — the start of the per-backend latency trajectory.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--backend-sweep]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax


def bench_kernels():
    """Pallas kernels (interpret mode on CPU): per-call wall time vs ref."""
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512))
    for name, fn in [
        ("kernel_lut_gelu", lambda: ops.lut_gelu(x)),
        ("ref_lut_gelu", lambda: ref.lut_gelu(x)),
        ("kernel_lut_softmax", lambda: ops.lut_softmax(x)),
        ("ref_lut_softmax", lambda: ref.lut_softmax(x)),
    ]:
        fn()
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn()
        jax.block_until_ready(out)
        print(f"{name},{(time.perf_counter()-t0)/5*1e6:.1f},interpret_mode")
    q = jax.random.normal(key, (1, 4, 128, 64))
    k = jax.random.normal(key, (1, 2, 128, 64))
    t0 = time.perf_counter()
    out = ops.lut_attention(q, k, k)
    jax.block_until_ready(out)
    print(f"kernel_lut_attention,{(time.perf_counter()-t0)*1e6:.1f},"
          "interpret_mode_single_call")


def bench_backend_sweep(out_path: str = "BENCH_runtime.json",
                        batch: int = 64, reps: int = 20,
                        warmup: int = 3,
                        history: str | None = None) -> dict:
    """Per-backend forward latency of the Engine the launchers actually
    serve (runtime.compile_model on KWT-Tiny), emitted as JSON.

    Timing protocol: ``warmup`` calls are discarded (compile + cache
    effects), then ``reps`` calls are timed per call with a sync each —
    those samples feed the telemetry latency schema (``mean_us``/
    ``p50_us``/``p95_us``/``p99_us``, the same field names the serve
    metrics export; ``mean_us`` is the trajectory + ledger figure).

    A final traced pass (``telemetry.tracing``) attributes each forward
    to its stage spans: ``unpack_us`` (jitted QTensor dequant — 0 for
    integer-executing plans, which have no unpack stage at all) and
    ``encode_us`` (the model executable), plus ``span_coverage_pct``
    (named children / forward wall time) and
    ``telemetry_overhead_pct`` (traced vs untraced per-call mean).

    ``packed_rom_bytes`` is the TRUE packed integer weight image
    (``Engine.rom_bytes``: int8, or nibble-packed int4 for the extra
    ``lut@int4`` row); ``lut_bytes`` the 2.69 kB LUT bank.

    Each row also carries the static-analysis verdict for its plan:
    ``float_leak_count`` (residency pass: int->float casts in the unpack
    stage — the number that must reach zero for full-integer execution)
    and ``ram_budget_bytes`` (budget pass: ROM + LUT + peak activation
    live-set, the figure gated against the paper's 64 kB target).

    Cost accounting (repro.perf): every row carries the static cost
    model's ``flops`` / ``bytes_moved`` / ``arithmetic_intensity`` for
    its plan, the achieved fraction of the *calibrated host roofline*
    at that intensity (``achieved_pct_of_roof`` + compute/memory
    ``bound`` verdict — the ROADMAP's achieved-vs-peak column), and
    ``est_mcu_cycles``: the per-sample plan priced on the paper's RV32
    MCU model, the unit of the paper's 26M → 5.5M ledger.  With
    ``history`` set, every row is also appended to the bench ledger
    (``repro.perf.ledger``) for the CI regression gate, plus a derived
    ``lut_over_float`` ratio entry (lut mean_us / float mean_us) so the
    gate guards the int-exec plan staying FASTER than float."""
    import numpy as np

    from repro import analysis, perf, runtime, telemetry
    from repro.configs import registry
    from repro.models import kwt

    cfg = registry.get("kwt-tiny").config
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                (batch, *cfg.input_dim))
    machine = perf.host_machine()
    prov = perf.provenance(machine)
    plans = [(name, None) for name in runtime.available_backends()]
    plans.append(("lut", runtime.QuantRecipe.from_config(
        cfg, bits=4).calibrated(params)))          # the int4 storage row
    # Compile + warm every plan FIRST, then round-robin the timed reps
    # across all of them.  On a shared CI core, sequential per-backend
    # windows alias scheduler noise onto whichever backend ran during a
    # burst — the gated lut/float ratio flipped sign run-to-run.
    # Interleaving makes each backend's samples face the same noise
    # process, so cross-backend ratios are paired statistics.
    engines = []
    for name, recipe in plans:
        eng = runtime.compile_model(cfg, params, backend=name, recipe=recipe)
        for _ in range(max(warmup, 1)):              # compile + warm, discard
            jax.block_until_ready(eng.forward(x))
        engines.append((name, recipe, eng, []))
    for _ in range(reps):
        for _, _, eng, samples in engines:           # per-call, synced
            t1 = time.perf_counter()
            jax.block_until_ready(eng.forward(x))
            samples.append((time.perf_counter() - t1) * 1e6)
    results = []
    for name, recipe, eng, samples in engines:
        lat = telemetry.latency_summary(samples, unit="us")
        us = lat["mean_us"]
        with telemetry.tracing() as tr:              # stage attribution
            for _ in range(reps):
                eng.forward(x)
        ups = tr.durations_us("unpack")              # absent for int-exec
        unpack_us = float(np.mean(ups)) if len(ups) else 0.0
        encode_us = float(np.mean(tr.durations_us("encode")))
        traced_us = float(np.median(tr.durations_us("forward")))
        coverage = telemetry.span_coverage(tr, "forward")
        # median-vs-median: per-call means on a shared CPU are dominated
        # by scheduler noise, which would read as fake "overhead"
        overhead = 100.0 * (traced_us - lat["p50_us"]) / lat["p50_us"]
        bits = eng.recipe.bits if eng.recipe is not None else None
        label = name if recipe is None else f"{name}@int{bits}"
        rep = analysis.check_engine(eng, passes=("residency", "budget"))
        leaks = rep.result("residency").metrics["float_leak_count"]
        ram = rep.result("budget").metrics["total_bytes"]
        cost = perf.engine_cost(eng, batch=batch)
        cost1 = perf.engine_cost(eng, batch=1)     # per-sample, MCU units
        row = {"backend": label,
               **lat,
               **perf.roofline_terms(cost.flops, cost.bytes, us / 1e6,
                                     machine),
               "est_mcu_cycles": round(perf.PAPER_MCU.cycles(cost1.flops,
                                                             cost1.bytes)),
               "unpack_us": round(unpack_us, 1),
               "encode_us": round(encode_us, 1),
               "span_coverage_pct": round(100.0 * coverage, 1),
               "telemetry_overhead_pct": round(overhead, 2),
               "warmup": warmup,
               "batch": batch, "interpret": eng.interpret,
               "packed_rom_bytes": eng.rom_bytes,
               "lut_bytes": eng.lut_bytes,
               "param_bytes": eng.param_bytes,
               "int_resident": eng.int_resident,
               "int_exec": eng.int_exec, "bits": bits,
               "float_leak_count": leaks,
               "ram_budget_bytes": ram,
               "analysis_ok": rep.ok}
        results.append(row)
        print(f"backend_{label},{us:.1f},p50={lat['p50_us']}us;"
              f"p95={lat['p95_us']}us;unpack={unpack_us:.1f}us;"
              f"encode={encode_us:.1f}us;rom={eng.rom_bytes}B;"
              f"lut={eng.lut_bytes}B;params={eng.param_bytes}B;"
              f"leaks={leaks};ram={ram}B;roof={row['achieved_pct_of_roof']}"
              f"%({row['bound']});interpret={eng.interpret}")
    report = {"arch": "kwt-tiny", "batch": batch, "reps": reps,
              "warmup": warmup, "device": jax.default_backend(),
              "provenance": prov, "machine": machine.to_dict(),
              "results": results}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", file=sys.stderr)
    if history:
        entries = [
            perf.entry("kwt-tiny", r["backend"], batch,
                       r["mean_us"], "mean_us",
                       rom_bytes=r["packed_rom_bytes"],
                       extra={"achieved_pct_of_roof":
                              r["achieved_pct_of_roof"],
                              "achieved_pct_of_peak":
                              r["achieved_pct_of_peak"],
                              "bound": r["bound"],
                              "est_mcu_cycles": r["est_mcu_cycles"]},
                       prov=prov)
            for r in results]
        by_backend = {r["backend"]: r for r in results}
        if "float" in by_backend and "lut" in by_backend:
            # the int-exec acceptance as a guarded ledger figure: lut
            # beating float means ratio < 1, and `perf regress` flags
            # any >15% growth — the unpack-tax win cannot silently rot
            ratio = by_backend["lut"]["mean_us"] / \
                by_backend["float"]["mean_us"]
            entries.append(perf.entry(
                "kwt-tiny", "lut_over_float", batch, round(ratio, 4),
                "ratio_mean_us", rom_bytes=0, prov=prov))
        n = perf.append(history, entries)
        print(f"appended {n} entries to {history}", file=sys.stderr)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the trained-model tables (fast CI mode)")
    ap.add_argument("--backend-sweep", action="store_true",
                    help="per-backend Engine forward latency -> "
                         "BENCH_runtime.json (skips the paper tables)")
    ap.add_argument("--out", default="BENCH_runtime.json")
    ap.add_argument("--batch", type=int, default=64,
                    help="sweep batch size (CI smoke uses a small one)")
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--history", default=None,
                    help="append sweep rows to this bench ledger "
                         "(BENCH_history.jsonl) for repro.perf regress")
    args = ap.parse_args()

    if args.backend_sweep:
        print("name,us_per_call,derived")
        bench_backend_sweep(args.out, batch=args.batch, reps=args.reps,
                            history=args.history)
        return

    from benchmarks import paper_tables as pt

    print("name,us_per_call,derived")
    bench_kernels()
    pt.bench_custom_ops()       # Table VII
    pt.bench_lut_cost()         # Table VIII analogue
    pt.bench_op_profile()       # Figs 3-5
    pt.bench_gelu_approx()      # Fig 7
    if not args.quick:
        fam = pt.bench_model_family()    # Tables I/III/IV (trains KWT-Tiny)
        trained = fam.get("trained")
        pt.bench_scale_sweep(trained)    # Table V
        pt.bench_inference_profile(trained)  # Table IX
    print("benchmarks complete.", file=sys.stderr)


if __name__ == "__main__":
    main()
