"""Benchmark harness: one function per paper table (benchmarks.paper_tables)
plus kernel micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp


def bench_kernels():
    """Pallas kernels (interpret mode on CPU): per-call wall time vs ref."""
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 512))
    for name, fn in [
        ("kernel_lut_gelu", lambda: ops.lut_gelu(x)),
        ("ref_lut_gelu", lambda: ref.lut_gelu(x)),
        ("kernel_lut_softmax", lambda: ops.lut_softmax(x)),
        ("ref_lut_softmax", lambda: ref.lut_softmax(x)),
    ]:
        fn()
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn()
        jax.block_until_ready(out)
        print(f"{name},{(time.perf_counter()-t0)/5*1e6:.1f},interpret_mode")
    q = jax.random.normal(key, (1, 4, 128, 64))
    k = jax.random.normal(key, (1, 2, 128, 64))
    t0 = time.perf_counter()
    out = ops.lut_attention(q, k, k)
    jax.block_until_ready(out)
    print(f"kernel_lut_attention,{(time.perf_counter()-t0)*1e6:.1f},"
          "interpret_mode_single_call")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the trained-model tables (fast CI mode)")
    args = ap.parse_args()

    from benchmarks import paper_tables as pt

    print("name,us_per_call,derived")
    bench_kernels()
    pt.bench_custom_ops()       # Table VII
    pt.bench_lut_cost()         # Table VIII analogue
    pt.bench_op_profile()       # Figs 3-5
    pt.bench_gelu_approx()      # Fig 7
    if not args.quick:
        fam = pt.bench_model_family()    # Tables I/III/IV (trains KWT-Tiny)
        trained = fam.get("trained")
        pt.bench_scale_sweep(trained)    # Table V
        pt.bench_inference_profile(trained)  # Table IX
    print("benchmarks complete.", file=sys.stderr)


if __name__ == "__main__":
    main()
