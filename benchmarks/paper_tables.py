"""One benchmark per paper table/figure.  Each function prints
``name,us_per_call,derived`` CSV rows (plus a human-readable block) and
returns a dict for benchmarks.run to aggregate.

Paper artefacts covered:
  Table I/III/IV  -> bench_model_family   (KWT-1 vs KWT-Tiny params/size/acc)
  Table V         -> bench_scale_sweep    (scale-factor accuracy sweep)
  Table VII       -> bench_custom_ops     (the five ALU behaviours, timed)
  Table VIII      -> bench_lut_cost       (ROM bytes; TPU-side analogue)
  Table IX        -> bench_inference_profile (float vs quantised vs +LUT)
  Fig 3-5         -> bench_op_profile     (per-op cost share of inference)
  Fig 7           -> bench_gelu_approx    (GELU approximation error)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import approx, calibrate, fixedpoint as fxp, lut, quant
from repro.data import pipeline
from repro.models import kwt
from repro.optim import adamw


def _time(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _train_kwt(cfg, steps=300, seed=0):
    hp = adamw.HParams(lr=3e-3, warmup_steps=20, total_steps=steps,
                       weight_decay=0.0)
    params = kwt.init_params(cfg, jax.random.PRNGKey(seed))
    state = adamw.init(params, hp)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(kwt.loss_fn)(params, batch, cfg)
        params, state, _ = adamw.update(grads, state, params, hp,
                                        scan_stacked=False)
        return params, state, loss

    for i in range(steps):
        params, state, _ = step(params, state, pipeline.keyword_batch(
            seed, i, batch=64, input_dim=cfg.input_dim,
            n_classes=cfg.n_classes))
    return params


def _accuracy(cfg, params, n=512):
    correct = total = 0
    for b in pipeline.gsc_eval_set(0, n=n, input_dim=cfg.input_dim,
                                   n_classes=cfg.n_classes):
        pred = jnp.argmax(kwt.forward(params, b["mfcc"], cfg), -1)
        correct += int(jnp.sum(pred == b["labels"]))
        total += int(b["labels"].size)
    return correct / total


def bench_model_family():
    """Tables I/III/IV: KWT-1 vs KWT-Tiny parameters / memory / accuracy."""
    rows = []
    out = {}
    for name, paper_params, paper_mem in [("kwt-1", 607_000, 2.42e6),
                                          ("kwt-tiny", 1646, 6584)]:
        cfg = registry.get(name).config
        params = kwt.init_params(cfg, jax.random.PRNGKey(0))
        n = kwt.count_params(params)
        mem = 4 * n
        t = _time(jax.jit(lambda x, p=params, c=cfg: kwt.forward(p, x, c)),
                  jnp.zeros((1, cfg.input_dim[0], cfg.input_dim[1])))
        rows.append(f"table3_{name},{t:.1f},params={n};float_bytes={mem}")
        out[name] = {"params": n, "bytes": mem, "paper_params": paper_params}
    ratio = out["kwt-1"]["params"] / out["kwt-tiny"]["params"]
    rows.append(f"table4_size_ratio,0,{ratio:.0f}x_smaller(paper=369x)")
    # accuracy on the synthetic GSC surrogate (2-class, paper's task shape)
    cfg = registry.get("kwt-tiny").config
    params = _train_kwt(cfg)
    acc = _accuracy(cfg, params)
    rows.append(f"table4_kwt_tiny_acc,0,accuracy={acc:.3f}(paper=0.872)")
    out["acc_float"] = acc
    out["trained"] = params
    print("\n".join(rows))
    return out


def bench_scale_sweep(trained=None):
    """Table V: accuracy per (weight 2^y, input 2^y) pair."""
    cfg = registry.get("kwt-tiny").config
    params = trained or _train_kwt(cfg)
    batches = [(b["mfcc"], b["labels"]) for b in
               pipeline.gsc_eval_set(0, n=512, input_dim=cfg.input_dim)]
    pairs = [(3, 3), (4, 4), (5, 5), (6, 5), (6, 6)]     # = Table V rows
    res = calibrate.sweep_scale_factors(
        lambda p, x: kwt.forward(p, x, cfg), params, batches, pairs=pairs)
    paper = {(3, 3): 0.603, (4, 4): 0.71, (5, 5): 0.773,
             (6, 5): 0.825, (6, 6): 0.652}
    for r in res:
        key = (r.weight_exponent, r.input_exponent)
        print(f"table5_w{2**r.weight_exponent}_i{2**r.input_exponent},0,"
              f"acc={r.accuracy:.3f}(paper={paper[key]});"
              f"qbytes={r.quantized_bytes}")
    best = calibrate.best_pair(res)
    print(f"table5_best,0,w=2^{best.weight_exponent};i=2^{best.input_exponent}")
    return {"sweep": [(r.weight_exponent, r.input_exponent, r.accuracy)
                      for r in res]}


def bench_custom_ops():
    """Table VII: the five custom ALU behaviours, vectorised, timed."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 1024)) * 3
    bank = lut.make_lut_bank()
    ops = {
        "ALU_EXP": jax.jit(lambda z: jnp.take(
            jnp.asarray(bank.exp_q24),
            lut.exp_index_from_q24(fxp.to_fixed(jnp.abs(z))))),
        "ALU_INVERT": jax.jit(lambda z: lut.reciprocal_q24(
            fxp.to_fixed(jnp.abs(z) + 1.0), bank)),
        "ALU_GELU": jax.jit(lambda z: approx.gelu(z, mode="lut")),
        "ALU_TO_FIXED": jax.jit(fxp.to_fixed),
        "ALU_TO_FLOAT": jax.jit(lambda z: fxp.to_float(fxp.to_fixed(z))),
    }
    out = {}
    for name, fn in ops.items():
        t = _time(fn, x)
        per_elem_ns = t * 1e3 / x.size
        print(f"table7_{name},{t:.1f},ns_per_element={per_elem_ns:.3f}")
        out[name] = t
    return out


def bench_lut_cost():
    """Table VIII analogue: ROM/VMEM cost of the acceleration (the FPGA
    LUT/DSP/FF columns have no TPU analogue; DESIGN.md §2)."""
    bank = lut.make_lut_bank()
    print(f"table8_rom_bytes,0,{bank.rom_bytes}(paper=2.69kB)")
    vmem_frac = bank.rom_bytes / 16e6
    print(f"table8_vmem_fraction,0,{vmem_frac:.2e}_of_16MB_VMEM")
    return {"rom_bytes": bank.rom_bytes}


def bench_inference_profile(trained=None):
    """Table IX: float vs quantised vs quantised+LUT — time + accuracy.

    The paper's cycle counts (26M/13M/5.5M on a 50 MHz scalar core) map to
    relative wall-time of the three numerical paths here; absolute CPU
    microseconds are NOT cycle-accurate claims.
    """
    from repro import runtime

    cfg = registry.get("kwt-tiny").config
    params = trained or _train_kwt(cfg)
    x = pipeline.keyword_batch(0, 999, batch=64, input_dim=cfg.input_dim)
    recipe = runtime.QuantRecipe.from_config(cfg)

    variants = {
        "float": runtime.compile_model(cfg, params, backend="float"),
        "quantised": runtime.compile_model(cfg, params, backend="float",
                                           recipe=recipe),
        "quantised_lut": runtime.compile_model(cfg, params, backend="lut"),
    }
    paper_cycles = {"float": 26e6, "quantised": 13e6, "quantised_lut": 5.5e6}
    out = {}
    for name, eng in variants.items():
        t = _time(eng.forward, x["mfcc"])
        acc = _accuracy(eng.exec_cfg, eng.params)
        print(f"table9_{name},{t:.1f},acc={acc:.3f};paper_cycles="
              f"{paper_cycles[name]:.1e}")
        out[name] = {"us": t, "acc": acc}
    return out


def bench_op_profile():
    """Figs 3-5: per-op share of a KWT-Tiny inference (FLOP counting via
    jaxpr-free analytic op model, mirroring the paper's profiling split)."""
    cfg = registry.get("kwt-tiny").config
    f, t = cfg.input_dim
    s, d, dh, mlp = t + 1, cfg.d_model, cfg.resolved_head_dim, cfg.d_ff
    ops = {
        "matmul_proj": 2 * s * f * d + 2 * s * d * cfg.n_classes,
        "matmul_qkv": 3 * 2 * s * d * dh,
        "matmul_attn": 2 * 2 * s * s * dh,
        "matmul_out": 2 * s * dh * d,
        "matmul_mlp": 2 * 2 * s * d * mlp,
        "softmax": 10 * s * s,          # exp+div dominated (paper Fig 4)
        "gelu": 25 * s * mlp,           # erf cost model (paper Fig 5)
        "layernorm": 8 * s * d,
    }
    total = sum(ops.values())
    for k, v in sorted(ops.items(), key=lambda kv: -kv[1]):
        print(f"fig3_{k},0,share={v/total:.2%}")
    return {"profile": {k: v / total for k, v in ops.items()}}


def bench_gelu_approx():
    """Fig 7: GELU LUT approximation error over [-4, 4]."""
    xs = jnp.linspace(-4.0, 4.0, 4001)
    exact = jax.nn.gelu(xs, approximate=False)
    for mode in ("lut", "lut_interp"):
        err = jnp.abs(approx.gelu(xs, mode=mode) - exact)
        print(f"fig7_{mode},0,max_err={float(jnp.max(err)):.4f};"
              f"mean_err={float(jnp.mean(err)):.5f}")
    # end-task degradation (the paper's 0.0042% is end-task, not pointwise)
    return {"max_err": float(jnp.max(jnp.abs(
        approx.gelu(xs, "lut") - exact)))}
