"""§Perf hillclimb driver: per-iteration lower/compile of a cell variant,
tagged JSON artifacts (results/dryrun/<cell>__<tag>.json), and a printed
before/after versus the paper-faithful baseline.

Cells (chosen per the assignment rule):
  H1 qwen2.5-14b x train_4k   — worst roofline fraction among dense train
                                 cells with co-dominant memory+collective
  H2 rwkv6-3b    x train_4k   — most collective-bound cell
  H3 qwen2.5-14b x decode_32k — most representative of the paper's
                                 technique (quantised serving)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--cell H1|H2|H3] [--it N]
"""

from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro import runtime
from repro.configs import registry
from repro.configs.base import QuantConfig


def run_variant(arch, shape_name, tag, cfg_override, seq_axis=None,
                micro_override=None):
    from repro.launch import dryrun, mesh as meshlib, steps

    entry = registry.get(arch)
    shape = {s.name: s for s in entry.shapes}[shape_name]
    cfg = cfg_override(entry.config)
    mesh = meshlib.make_production_mesh()
    fname = os.path.join(dryrun.RESULTS_DIR,
                         f"{arch}__{shape_name}__single__{tag}.json")
    if os.path.exists(fname):
        with open(fname) as f:
            return json.load(f)

    # lower the full program (memory proof) + cost components
    prog = _build(cfg, shape, mesh, steps)
    lowered = steps.lower_program(prog, mesh, seq_axis=seq_axis)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    conv = dryrun.cpu_convert_overhead(compiled.as_text())
    rec = {"arch": arch, "shape": shape_name, "mesh": "single", "tag": tag,
           "memory": {
               "peak_bytes_est": int(ma.argument_size_in_bytes
                                     + ma.output_size_in_bytes
                                     + ma.temp_size_in_bytes
                                     - ma.alias_size_in_bytes),
               "cpu_convert_overhead_bytes": int(conv)},
           "n_chips": int(mesh.devices.size)}
    rec["memory"]["peak_bytes_tpu_adjusted"] = \
        rec["memory"]["peak_bytes_est"] - int(conv)
    comps = []
    for cp in _cost_programs(cfg, shape, mesh, steps):
        c = dryrun.cost_of(
            steps.lower_program(cp, mesh, seq_axis=seq_axis).compile())
        comps.append((cp.name, cp.multiplier, c))
    cost = dryrun.combine(comps)
    rec["cost"] = cost
    rec["model_flops"] = dryrun.model_flops(cfg, shape)
    rec["roofline"] = dryrun.roofline(cost, mesh.devices.size)
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _build(cfg, shape, mesh, steps):
    # build_step_program reads registry config; we need the variant cfg
    import repro.launch.steps as S
    return _with_cfg(S.build_step_program, cfg, shape, mesh)


def _with_cfg(fn, cfg, shape, mesh):
    return fn(cfg, shape, mesh)


def _cost_programs(cfg, shape, mesh, steps):
    return steps.cost_programs(cfg, shape, mesh)


def show(tag, rec, base=None):
    rf = rec["roofline"]
    line = (f"{tag:24s} comp={rf['compute_s']:7.3f}s mem={rf['memory_s']:7.3f}s "
            f"coll={rf['collective_s']:7.3f}s dom={rf['dominant']:10s} "
            f"peak={rec['memory']['peak_bytes_tpu_adjusted']/1e9:6.2f}GB(adj)")
    if base is not None:
        brf = base["roofline"]
        dom = brf["dominant"] + "_s"
        delta = 1 - rf[dom] / max(brf[dom], 1e-12)
        line += f"  Δdominant(base)={delta:+.1%}"
    print(line, flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    args = ap.parse_args()

    if args.cell in ("H1", "all"):
        print("== H1: qwen2.5-14b x train_4k ==")
        base = run_variant("qwen2.5-14b", "train_4k", "baseline",
                           lambda c: c)
        show("baseline", base)
        it1 = run_variant("qwen2.5-14b", "train_4k", "it1_bf16scores",
                          lambda c: c.with_(scores_dtype="bfloat16"))
        show("it1_bf16scores", it1, base)
        it2 = run_variant("qwen2.5-14b", "train_4k", "it2_purefsdp",
                          lambda c: c.with_(scores_dtype="bfloat16",
                                            pure_fsdp=True))
        show("it2_+pure_fsdp", it2, base)
        it3 = run_variant("qwen2.5-14b", "train_4k", "it3_seqshard",
                          lambda c: c, seq_axis="model")
        show("it3_seqshard(SP)", it3, base)

    if args.cell in ("H2", "all"):
        print("== H2: rwkv6-3b x train_4k ==")
        base = run_variant("rwkv6-3b", "train_4k", "baseline", lambda c: c)
        show("baseline", base)
        it1 = run_variant("rwkv6-3b", "train_4k", "it1_headpad",
                          lambda c: c.with_(rwkv_head_pad=True))
        show("it1_headpad", it1, base)
        it2 = run_variant("rwkv6-3b", "train_4k", "it2_headpad_purefsdp",
                          lambda c: c.with_(rwkv_head_pad=True,
                                            pure_fsdp=True))
        show("it2_+pure_fsdp", it2, base)
        it3 = run_variant("rwkv6-3b", "train_4k", "it3_headpad_fusedproj",
                          lambda c: c.with_(rwkv_head_pad=True,
                                            rwkv_fused_proj=True))
        show("it3_headpad+fuse", it3, base)

    if args.cell in ("H3", "all"):
        print("== H3: qwen2.5-14b x decode_32k ==")
        base = run_variant("qwen2.5-14b", "decode_32k", "baseline",
                           lambda c: c)
        show("baseline", base)
        it1 = run_variant(
            "qwen2.5-14b", "decode_32k", "it1_int8kv",
            lambda c: c.with_(quant=QuantConfig(quantize_kv_cache=True)))
        show("it1_int8kv", it1, base)
        it2 = run_variant(
            "qwen2.5-14b", "decode_32k", "it2_int8kv_lut",
            lambda c: runtime.get_backend("lut_float").configure(
                c.with_(quant=QuantConfig(quantize_kv_cache=True))))
        show("it2_+lut(paper)", it2, base)
        it3 = run_variant(
            "qwen2.5-14b", "decode_32k", "it3_int8kv_tponly",
            lambda c: c.with_(quant=QuantConfig(quantize_kv_cache=True),
                              tp_only=True))
        show("it3_+tp_only", it3, base)


if __name__ == "__main__":
    main()
