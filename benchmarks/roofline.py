"""Roofline report for Engine plans: cost table + achieved-vs-peak CSV.

A thin CLI over :mod:`repro.perf` — calibrate the host, price each
backend's compiled plan with the static cost model, and print the
paper-style (stage, op) table plus one achieved-vs-peak row per
backend.  The sweep drivers (``benchmarks/run.py --backend-sweep``,
``benchmarks/stream_bench.py``) embed the same columns in their JSON
rows; this command is the standalone/inspection view.

  PYTHONPATH=src python -m benchmarks.roofline [--arch kwt-tiny]
      [--backends float lut pallas] [--batch 64] [--mcu] [--smoke]

``--mcu`` prices on the paper's RV32 MCU model (cycles, the 26M → 5.5M
unit) instead of the measured host roofline.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    import jax

    from repro import perf, runtime
    from repro.configs import registry
    from repro.launch import steps

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kwt-tiny")
    ap.add_argument("--backends", nargs="+",
                    default=["float", "lut_float", "lut", "pallas"])
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's smoke config")
    ap.add_argument("--mcu", action="store_true",
                    help="price on the paper's RV32 MCU model")
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch).smoke if args.smoke \
        else registry.get(args.arch).config
    params = steps.model_module(cfg).init_params(cfg, jax.random.PRNGKey(0))
    machine = perf.PAPER_MCU if args.mcu else perf.host_machine()
    print(f"machine: {machine.id} (ridge {machine.ridge:.2f} flops/byte)\n")

    summary = ["backend,flops,bytes_moved,arithmetic_intensity,bound,"
               "roof_time_us,est_cycles"]
    for backend in args.backends:
        eng = runtime.compile_model(cfg, params, backend=backend)
        rep = perf.engine_cost(eng, batch=args.batch)
        print(f"## {args.arch} · backend={backend} · batch={args.batch}")
        print(rep.table(machine))
        print()
        summary.append(
            f"{backend},{rep.flops:.0f},{rep.bytes:.0f},"
            f"{rep.intensity:.4f},{machine.verdict(rep.intensity)},"
            f"{machine.time_s(rep.flops, rep.bytes) * 1e6:.1f},"
            f"{machine.cycles(rep.flops, rep.bytes):.0f}")
    print("\n".join(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
