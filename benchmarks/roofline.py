"""Roofline report generator: reads results/dryrun/*.json (written by
launch/dryrun.py) and emits the §Dry-run and §Roofline markdown tables for
EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--tag TAG]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["granite-moe-3b-a800m", "deepseek-moe-16b", "chameleon-34b",
              "whisper-large-v3", "hymba-1.5b", "rwkv6-3b",
              "nemotron-4-340b", "granite-8b", "internlm2-1.8b",
              "qwen2.5-14b"]


def load(results_dir: str, tag: str = ""):
    recs = {}
    for f in glob.glob(os.path.join(results_dir, f"*{tag}.json")):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("tag"):          # hillclimb variants live in §Perf, not here
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs, mesh="single"):
    rows = ["| arch | shape | compile | peak GB/dev raw (TPU-adj) | fits 16GB | "
            "per-dev GFLOP | per-dev GB moved | collective MB |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if "skipped" in r:
                rows.append(f"| {arch} | {shape} | — | — | skip | — | — | — |"
                            f" <!-- {r['skipped']} -->")
                continue
            m = r["memory"]
            c = r.get("cost") or r["full_program_cost_raw"]
            adj = m.get("peak_bytes_tpu_adjusted", m["peak_bytes_est"])
            rows.append(
                f"| {arch} | {shape} | {r.get('compile_s', 0):.0f}s "
                f"| {m['peak_bytes_est']/1e9:.2f} ({adj/1e9:.2f} adj) "
                f"| {'YES' if adj <= 16e9 else '**NO**'} "
                f"| {c['flops']/1e9:.0f} | {c['bytes']/1e9:.1f} "
                f"| {c['collective_bytes']/1e6:.0f} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "MODEL/HLO flops | roofline fraction |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "single"))
            if r is None or "skipped" in r or "roofline" not in r:
                if r is not None and "skipped" in r:
                    rows.append(f"| {arch} | {shape} | — | — | — | skip | — | — |")
                continue
            rf = r["roofline"]
            dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            # roofline fraction: useful-compute time / dominant-term time
            useful_s = (r["model_flops"] / r["n_chips"]) / 197e12
            frac = useful_s / max(dom, 1e-12)
            rows.append(
                f"| {arch} | {shape} | {fmt_s(rf['compute_s'])} "
                f"| {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} "
                f"| **{rf['dominant']}** | {r['model_to_hlo']:.2f} "
                f"| {frac:.1%} |")
    return "\n".join(rows)


def collective_summary(recs, mesh="single"):
    rows = []
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or "skipped" in r or "cost" not in r:
            continue
        colls = {}
        for comp in r["cost"]["components"]:
            for k, v in comp.get("collectives", {}).items():
                colls[k] = colls.get(k, 0) + comp["multiplier"] * v
        top = ", ".join(f"{k}={v/1e6:.0f}MB" for k, v in
                        sorted(colls.items(), key=lambda kv: -kv[1])[:3])
        rows.append(f"- {arch} x {shape}: {top}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "dryrun"))
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(args.results, args.tag)
    print("## Dry-run (single pod, 16x16 = 256 chips)\n")
    print(dryrun_table(recs, "single"))
    print("\n## Dry-run (multi-pod, 2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single pod; v5e: 197TF bf16, 819GB/s HBM, "
          "50GB/s ICI)\n")
    print(roofline_table(recs))
    print("\n## Dominant collectives per cell\n")
    print(collective_summary(recs))


if __name__ == "__main__":
    main()
