"""Serving-cell benchmark: per-hop latency, real-time factor, LM tokens/s.

Every row is produced through :class:`repro.cell.ServeCell` — the same
lane pool, fused engine+detector hop, and metrics ledger the serve
launchers run — not a bench-only loop.  Two ingest modes, reported
side by side:

* ``audio``: lanes ingest raw waveform chunks and the cell runs the
  full MFCC frontend per hop.  This includes the FFT, which is the
  dominant per-hop cost at wide batches.
* ``feature``: lanes ingest pre-featurised MFCC frames
  (``stream.engine.stream_step_frames``) — the paper's deployment
  split, where the MCU next to the microphone owns featurisation and
  the cell serves the encoder+detector.  Frames from
  ``features.frontend_push`` are bit-identical to the audio path
  (tests/test_cell.py), so this row measures the same model, minus the
  edge-resident stage.

RTF (real-time factor) = wall time per hop / audio time per hop: every
stream delivers ``chunk_hops * hop_len`` samples per step, and the
whole packed batch must be processed inside that budget regardless of
width — RTF < 1 means the cell keeps up with all N streams on this
host.  Wide-stream rows use ``chunk_hops`` > 1 (the admission
controller's degrade mode) to amortise the per-step encoder pass.

The ``lm`` section drives :class:`repro.cell.scheduler.LMScheduler`
(continuous batching) at mixed prefill/decode load and reports
decoded tokens/s.

Usage:  PYTHONPATH=src python -m benchmarks.stream_bench \
            [--streams 1 64 1024 4096] [--hops 50] [--out BENCH_stream.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import cell as cellmod
from repro import perf
from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.launch import steps
from repro.models import kwt
from repro.stream import detector as det
from repro.stream import engine
from repro.stream import features


def bench_one(eng, fcfg, dcfg, n_streams: int, hops: int, chunk_hops: int,
              ingest: str, seed: int = 0) -> dict:
    """Time ``hops`` cell hops at ``n_streams`` fully occupied lanes."""
    k = chunk_hops
    cfg = eng.exec_cfg
    rng = np.random.RandomState(seed)
    cell = cellmod.ServeCell(eng, slots=n_streams,
                             registry=telemetry.Registry())
    with cell:
        lanes = cell.stream_lanes(fcfg, dcfg, chunk_hops=k,
                                  feature_ingest=(ingest == "feature"))
        for lane in range(n_streams):
            lanes.join(lane)
        if ingest == "feature":
            chunk = 0.1 * rng.randn(n_streams, k,
                                    cfg.input_dim[0]).astype(np.float32)
        else:
            chunk = 0.1 * rng.randn(n_streams,
                                    k * fcfg.hop_len).astype(np.float32)
        chunk = jax.device_put(chunk)

        # warm-up (discarded): compile + fill the receptive field
        warm_hops = engine.window_frames(cfg) // k + 2
        for _ in range(warm_hops):
            lanes.hop(chunk)

        # per-hop samples; lanes.hop syncs on the detector events each
        # call — the real serving cadence (events are consumed on host
        # every hop), so these samples ARE the serve-path latency.
        samples = []
        t0 = time.perf_counter()
        for _ in range(hops):
            t1 = time.perf_counter()
            lanes.hop(chunk)
            samples.append((time.perf_counter() - t1) * 1e3)
        dt = time.perf_counter() - t0
        assert int(cell.metrics.hops.value) == (warm_hops + hops) * k \
            * n_streams and cell.metrics.dropped_hops.value == 0

    per_step_ms = dt / hops * 1e3
    audio_ms = k * fcfg.hop_len / fcfg.sample_rate * 1e3
    rtf = per_step_ms / audio_ms
    return {"streams": n_streams, "ingest": ingest, "chunk_hops": k,
            "warmup_hops": warm_hops,
            "per_step_ms": round(per_step_ms, 4),
            **telemetry.latency_summary(samples, unit="ms"),
            "rtf": round(rtf, 5),
            "aggregate_realtime_x": round(n_streams / rtf, 1)}


def bench_lm(backend: str, slots: int, requests: int, max_len: int,
             seed: int = 0) -> dict:
    """Continuous-batching throughput: tokens/s at mixed prefill/decode
    load (new requests prefill into free lanes while residents decode)."""
    cfg = registry.get("internlm2-1.8b").smoke
    params = steps.model_module(cfg).init_params(cfg,
                                                 jax.random.PRNGKey(seed))
    eng = runtime.compile_model(cfg, params, backend=backend)
    rng = np.random.RandomState(seed)
    reqs = [(i, rng.randint(0, cfg.vocab_size,
                            size=rng.randint(4, max_len // 4)),
             int(rng.randint(4, max_len // 2))) for i in range(requests)]
    cell = cellmod.ServeCell(eng, slots=slots, registry=telemetry.Registry())
    with cell:
        sched = cell.lm_scheduler(max_len=max_len)
        for rid, prompt, gen in reqs:
            sched.submit(rid, prompt, gen)
        sched.run()          # warm-up: compile prefill/decode variants
        for rid, prompt, gen in reqs:
            sched.submit(rid, prompt, gen)
        t0 = time.perf_counter()
        out = sched.run()
        dt = time.perf_counter() - t0
    decoded = sum(len(v) for v in out.values())
    m = cell.metrics
    return {"arch": "internlm2-1.8b", "mode": backend, "slots": slots,
            "requests": requests, "max_len": max_len,
            "decode_tokens": decoded,
            "prefill_tokens": int(m.prefill_tokens.value) // 2,
            "wall_s": round(dt, 4),
            "tokens_per_s": round(decoded / dt, 2),
            "ms_per_token": round(1e3 * dt / max(decoded, 1), 4),
            "packed_rom_bytes": eng.rom_bytes}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kwt-tiny")
    ap.add_argument("--streams", type=int, nargs="+",
                    default=[1, 64, 1024, 4096])
    ap.add_argument("--hops", type=int, default=50)
    ap.add_argument("--chunk-hops", type=int, default=1,
                    help="hops per step for the audio-ingest rows")
    ap.add_argument("--wide-chunk-hops", type=int, default=None,
                    help="hops per step for wide-batch rows (default: the "
                         "full window, the deepest degrade the ring admits)")
    ap.add_argument("--wide-streams", type=int, default=4096,
                    help="rows at/above this width also run feature ingest "
                         "and the widened chunk")
    ap.add_argument("--backends", nargs="+", default=["float", "lut"],
                    help="runtime backends to sweep (pallas interpret is "
                         "slow on CPU; add it explicitly when wanted)")
    ap.add_argument("--lm-slots", type=int, default=4)
    ap.add_argument("--lm-requests", type=int, default=16)
    ap.add_argument("--lm-max-len", type=int, default=64)
    ap.add_argument("--no-lm", action="store_true")
    ap.add_argument("--out", default="BENCH_stream.json")
    ap.add_argument("--history", default=None,
                    help="append sweep rows to this bench ledger "
                         "(BENCH_history.jsonl) for repro.perf regress")
    args = ap.parse_args(argv)

    base = registry.get(args.arch).smoke
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()
    params = kwt.init_params(base, jax.random.PRNGKey(0))
    wide_k = args.wide_chunk_hops if args.wide_chunk_hops is not None \
        else engine.window_frames(base)
    machine = perf.host_machine()
    prov = perf.provenance(machine)

    results = []
    print("mode,ingest,streams,chunk_hops,per_step_ms,p50_ms,p95_ms,rtf,"
          "aggregate_realtime_x,roof_pct,bound")
    for b in args.backends:
        eng = runtime.compile_model(base, params, backend=b)
        for n in args.streams:
            rows = [("audio", args.chunk_hops)]
            if n >= args.wide_streams:
                # wide batch: degraded chunk (audio) + edge-featurised
                # ingest — both honest cell modes, reported side by side
                rows += [("audio", wide_k), ("feature", wide_k)]
            for ingest, k in rows:
                r = {"mode": b,
                     **bench_one(eng, fcfg, dcfg, n, args.hops, k, ingest)}
                # static cost of exactly this hop program, roofed
                # against the calibrated host
                cost = perf.stream_hop_cost(
                    eng, fcfg, batch=n, chunk_hops=k,
                    feature_ingest=(ingest == "feature"))
                r.update(perf.roofline_terms(cost.flops, cost.bytes,
                                             r["per_step_ms"] / 1e3,
                                             machine))
                r["packed_rom_bytes"] = eng.rom_bytes
                results.append(r)
                print(f"{b},{ingest},{n},{k},{r['per_step_ms']},"
                      f"{r['p50_ms']},{r['p95_ms']},{r['rtf']},"
                      f"{r['aggregate_realtime_x']},"
                      f"{r['achieved_pct_of_roof']},{r['bound']}")

    report = {"arch": args.arch,
              "host": {"cpus": os.cpu_count(),
                       "backend": jax.default_backend()},
              "provenance": prov, "machine": machine.to_dict(),
              "frontend": {"sample_rate": fcfg.sample_rate,
                           "frame_len": fcfg.frame_len,
                           "hop_len": fcfg.hop_len,
                           "window_frames": engine.window_frames(base)},
              "results": results}
    if not args.no_lm:
        report["lm"] = [bench_lm(b, args.lm_slots, args.lm_requests,
                                 args.lm_max_len)
                        for b in args.backends]
        for r in report["lm"]:
            print(f"lm,{r['mode']},slots={r['slots']},"
                  f"req={r['requests']},tok/s={r['tokens_per_s']}")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    if args.history:
        entries = [perf.entry(
            args.arch, f"{r['mode']}/{r['ingest']}@k{r['chunk_hops']}",
            r["streams"], r["per_step_ms"], "ms_per_hop",
            rom_bytes=r["packed_rom_bytes"],
            extra={"rtf": r["rtf"],
                   "achieved_pct_of_roof": r["achieved_pct_of_roof"],
                   "bound": r["bound"]},
            prov=prov) for r in results]
        entries += [perf.entry(
            r["arch"], f"{r['mode']}/lm", r["slots"], r["ms_per_token"],
            "ms_per_token", rom_bytes=r["packed_rom_bytes"],
            extra={"tokens_per_s": r["tokens_per_s"]}, prov=prov)
            for r in report.get("lm", [])]
        print(f"appended {perf.append(args.history, entries)} entries "
              f"to {args.history}")

    worst_small = max((r["rtf"] for r in results if r["streams"] <= 64),
                      default=None)
    best_wide = min((r["rtf"] for r in results
                     if r["streams"] >= args.wide_streams), default=None)
    ok = True
    if worst_small is not None:
        ok &= worst_small < 1.0
        print(f"RTF @ <=64 streams (audio): {worst_small} "
              f"({'OK' if worst_small < 1.0 else 'OVER BUDGET'})")
    if best_wide is not None:
        ok &= best_wide < 1.0
        print(f"best RTF @ >={args.wide_streams} streams: {best_wide} "
              f"({'OK' if best_wide < 1.0 else 'OVER BUDGET'})")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
