"""Streaming-KWS benchmark: per-hop latency and real-time factor.

Measures the jitted ``stream.engine.stream_step`` (+ detector) server hop
at increasing concurrent-stream counts, float vs the quantised LUT-fixed
path, and emits ``BENCH_stream.json``.

RTF (real-time factor) = wall time per hop / audio time per hop: every
stream delivers ``hop_len`` samples (10 ms) per hop, and the whole packed
batch must be processed inside that budget regardless of width — RTF < 1
means the server keeps up with all N streams on this host.

Usage:  PYTHONPATH=src python -m benchmarks.stream_bench \
            [--streams 1 16 64] [--hops 50] [--out BENCH_stream.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.models import kwt
from repro.stream import detector as det
from repro.stream import engine
from repro.stream import features


def bench_one(cfg, fcfg, dcfg, params, n_streams: int, hops: int,
              chunk_hops: int, seed: int = 0) -> dict:
    k = chunk_hops
    chunk = 0.1 * jax.random.normal(
        jax.random.PRNGKey(seed), (n_streams, k * fcfg.hop_len))
    state = engine.init_stream_state(cfg, fcfg, n_streams,
                                     keep_features=False)
    dstate = det.detector_init(dcfg, n_streams)

    @jax.jit
    def step(params, state, dstate, chunk):
        state, logits = engine.stream_step(params, state, chunk, cfg, fcfg)
        dstate, events = det.detector_step(
            dstate, engine.posteriors(logits), dcfg, warm=engine.warm(state))
        return state, dstate, events

    # warm-up (discarded): compile + fill the receptive field
    warm_hops = engine.window_frames(cfg) // k + 2
    for _ in range(warm_hops):
        state, dstate, events = step(params, state, dstate, chunk)
    jax.block_until_ready(events["score"])

    # aggregate timing (async dispatch, one sync): the RTF figure
    t0 = time.perf_counter()
    for _ in range(hops):
        state, dstate, events = step(params, state, dstate, chunk)
    jax.block_until_ready(events["score"])
    dt = time.perf_counter() - t0

    # per-hop samples (synced each hop) -> the shared telemetry latency
    # schema, so BENCH_stream rows and the live serve_hop_latency_ms
    # histogram carry the same p50/p95/p99 field names.
    samples = []
    for _ in range(hops):
        t1 = time.perf_counter()
        state, dstate, events = step(params, state, dstate, chunk)
        jax.block_until_ready(events["score"])
        samples.append((time.perf_counter() - t1) * 1e3)

    per_step_ms = dt / hops * 1e3
    audio_ms = k * fcfg.hop_len / fcfg.sample_rate * 1e3
    rtf = per_step_ms / audio_ms
    return {"streams": n_streams, "chunk_hops": k,
            "warmup_hops": warm_hops,
            "per_step_ms": round(per_step_ms, 4),
            **telemetry.latency_summary(samples, unit="ms"),
            "rtf": round(rtf, 5),
            "aggregate_realtime_x": round(n_streams / rtf, 1)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kwt-tiny")
    ap.add_argument("--streams", type=int, nargs="+", default=[1, 16, 64])
    ap.add_argument("--hops", type=int, default=50)
    ap.add_argument("--chunk-hops", type=int, default=1)
    ap.add_argument("--backends", nargs="+", default=["float", "lut"],
                    help="runtime backends to sweep (pallas interpret is "
                         "slow on CPU; add it explicitly when wanted)")
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args(argv)

    base = registry.get(args.arch).smoke
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()
    params = kwt.init_params(base, jax.random.PRNGKey(0))

    modes = {}
    for b in args.backends:
        eng = runtime.compile_model(base, params, backend=b)
        modes[b] = (eng.exec_cfg, eng.params)
    results = []
    print("mode,streams,per_step_ms,p50_ms,p95_ms,rtf,aggregate_realtime_x")
    for mode, (cfg, p) in modes.items():
        for n in args.streams:
            r = {"mode": mode,
                 **bench_one(cfg, fcfg, dcfg, p, n, args.hops,
                             args.chunk_hops)}
            results.append(r)
            print(f"{mode},{n},{r['per_step_ms']},{r['p50_ms']},"
                  f"{r['p95_ms']},{r['rtf']},{r['aggregate_realtime_x']}")

    report = {"arch": args.arch,
              "frontend": {"sample_rate": fcfg.sample_rate,
                           "frame_len": fcfg.frame_len,
                           "hop_len": fcfg.hop_len,
                           "window_frames": engine.window_frames(base)},
              "results": results}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    worst = max((r["rtf"] for r in results if r["streams"] >= 64),
                default=None)
    if worst is not None:
        ok = worst < 1.0
        print(f"RTF @ >=64 streams: {worst} ({'OK' if ok else 'OVER BUDGET'})")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
