"""PTQ vs QAT vs QAT+KD at matched ROM bytes -> BENCH_qat.json.

The accuracy half of the paper's pipeline, measured end to end through
the SAME deployment artifact for every variant: each row is accuracy of
``runtime.compile_model(cfg, params, backend="lut", recipe=...)`` on the
2-class KWT-Tiny task — identical recipe, identical int8/ROM footprint,
only the *training* differs.

Rows per weight exponent (paper Table V best 2^6, plus the aggressive
2^1 / 2^0 rows where the eq-9 grid actually bites — at 2^6 this
surrogate's PTQ is near-lossless, exactly the paper's regime where
retraining matters most is the coarse-grid one):

  * ``ptq``     — float training, post-hoc eq-9 cast (the old pipeline)
  * ``qat``     — repro.qat fine-tune (fake-quant forward, STE), best
                  checkpoint by validation fold
  * ``qat_kd``  — QAT + distillation from a float KWT-1 teacher
                  (35-class fine-grained surrogate, reduced head,
                  surgeon-shrunk + retrained)

Accuracies are reported on a test fold disjoint from both the training
stream and the checkpoint-selection fold.

Usage:  PYTHONPATH=src python -m benchmarks.qat_bench [--quick]
            [--out BENCH_qat.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro import qat, runtime
from repro.configs import registry
from repro.data import pipeline
from repro.models import kwt
from repro.qat import distill as D


def make_eval(cfg, exec_cfg, seed, n):
    fwd = jax.jit(lambda p, x: kwt.forward(p, x, exec_cfg))
    batches = pipeline.gsc_eval_set(seed, n=n, input_dim=cfg.input_dim)

    def acc(deployed_params):
        correct = total = 0
        for b in batches:
            pred = jnp.argmax(fwd(deployed_params, b["mfcc"]), -1)
            correct += int(jnp.sum(pred == b["labels"]))
            total += int(b["labels"].size)
        return correct / total

    return acc


def build_teacher(cfg, steps, keep_layers, seed=0):
    """Float KWT-1 on the student grid -> surgeon shrink -> retrain ->
    35->2 head reduction (the qat.distill pipeline)."""
    tcfg = D.teacher_config(registry.get("kwt-1").config, cfg)
    tparams = D.train_teacher(tcfg, steps, seed=seed + 1, lr=1.5e-3)
    if keep_layers and keep_layers < tcfg.n_layers:
        cal = [pipeline.keyword_batch(seed + 2, i, batch=64,
                                      input_dim=tcfg.input_dim,
                                      n_classes=tcfg.n_classes)
               for i in range(2)]
        tparams, tcfg = D.shrink_teacher(tparams, tcfg, keep_layers, cal)
        tparams = D.train_teacher(tcfg, steps, seed=seed + 1, lr=1.5e-3,
                                  init_params=tparams)
    tparams = D.reduce_head(tparams)
    return D.DistillSpec(tparams, tcfg.with_(n_classes=cfg.n_classes),
                         alpha=0.3, temperature=2.0)


def bench_qat(out_path="BENCH_qat.json", *, float_steps=300, qat_steps=200,
              teacher_steps=300, teacher_keep=4, eval_n=2048,
              exponents=(6, 1, 0), seed=0):
    cfg = registry.get("kwt-tiny").config
    t_start = time.time()
    # distill.train_teacher is the generic float kwt training loop; on
    # the student config it trains the 2-class baseline
    fparams = D.train_teacher(cfg, float_steps, seed=seed, lr=3e-3)
    lut_cfg = runtime.get_backend("lut").configure(cfg)
    test = make_eval(cfg, lut_cfg, 0, eval_n)          # test fold
    acc_float = make_eval(cfg, cfg, 0, eval_n)(fparams)
    print(f"float accuracy: {acc_float:.3f}")

    distill = build_teacher(cfg, teacher_steps, teacher_keep, seed=seed)
    t_acc = make_eval(cfg, distill.teacher_cfg, 0, eval_n)(
        distill.teacher_params)
    print(f"teacher (reduced head) accuracy: {t_acc:.3f}")

    variants = []
    ok_qat = ok_kd = True
    for wexp in exponents:
        recipe = runtime.QuantRecipe.from_config(cfg, weight_exponent=wexp)
        eng = runtime.compile_model(cfg, fparams, backend="lut",
                                    recipe=recipe)
        # packed_rom_bytes: the TRUE packed weight image (Engine.rom_bytes
        # since the integer-resident-QTensor PR); lut_bytes: the 2.69 kB
        # LUT bank that rom_bytes used to report.
        packed_rom = eng.rom_bytes
        lut_bytes = eng.lut_bytes

        def row(name, acc):
            variants.append({
                "name": name, "weight_exponent": wexp,
                "accuracy": round(acc, 4),
                "packed_rom_bytes": packed_rom, "lut_bytes": lut_bytes,
                "recipe": recipe.to_dict()})
            print(f"w=2^{wexp} {name:7s}: {acc:.3f}  "
                  f"(rom {packed_rom} B, lut {lut_bytes} B)")

        acc_ptq = test(recipe.apply(fparams))
        row("ptq", acc_ptq)

        spec = qat.QATSpec(recipe)
        val = make_eval(cfg, lut_cfg, 5, eval_n)
        qp, qs = qat.finetune_qat(cfg, fparams, spec, qat_steps, seed=seed,
                                  lr=3e-3 if wexp <= 1 else 1e-3,
                                  select_fn=val)
        ex = qat.export(qp, spec, qs)
        acc_qat = test(ex.deployed_params)
        row("qat", acc_qat)
        ok_qat &= acc_qat >= acc_ptq - 0.02

        kd_spec = qat.QATSpec(recipe, qat.QATConfig(), distill=distill)
        qp, qs = qat.finetune_qat(cfg, fparams, kd_spec, qat_steps,
                                  seed=seed, fine_classes=35,
                                  lr=3e-3 if wexp <= 1 else 1e-3,
                                  select_fn=val)
        ex = qat.export(qp, kd_spec, qs)
        acc_kd = test(ex.deployed_params)
        row("qat_kd", acc_kd)
        ok_kd &= acc_kd >= acc_ptq - 0.02

    from repro import perf

    report = {
        "arch": "kwt-tiny", "task": "2-class keyword surrogate",
        "eval_n": eval_n, "float_steps": float_steps,
        "qat_steps": qat_steps, "float_accuracy": round(acc_float, 4),
        "teacher_accuracy": round(t_acc, 4),
        "device": jax.default_backend(),
        "provenance": perf.provenance(),
        "wall_s": round(time.time() - t_start, 1),
        "acceptance": {"qat_ge_ptq": bool(ok_qat),
                       "kd_ge_ptq": bool(ok_kd)},
        "variants": variants,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path} (acceptance: qat_ge_ptq={ok_qat}, "
          f"kd_ge_ptq={ok_kd})", file=sys.stderr)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (fewer steps, smaller eval)")
    ap.add_argument("--out", default="BENCH_qat.json")
    args = ap.parse_args()
    if args.quick:
        report = bench_qat(args.out, float_steps=150, qat_steps=100,
                           teacher_steps=150, eval_n=1024,
                           exponents=(6, 0))
    else:
        report = bench_qat(args.out)
    if not all(report["acceptance"].values()):
        print("FAIL: a QAT variant regressed below PTQ", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
