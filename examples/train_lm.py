"""End-to-end LM training driver with fault tolerance.

Default is a CPU-sized run; pass --d-model/--layers/--vocab for the ~100M
configuration (runtime on CPU is hours; the code path is identical to the
production launcher either way — checkpoint/restore, straggler monitor,
deterministic resume):

  # quick CPU demo (2-layer reduced granite-8b family):
  PYTHONPATH=src python examples/train_lm.py --steps 30

  # ~100M-parameter run (12L x 768d, a few hundred steps):
  PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300 \
      --ckpt-dir /tmp/lm100m
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import registry
from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.hundred_m:
        # ~110M params: 12L x 768d x 32k vocab (llama-family)
        import repro.configs.granite_8b as g
        cfg = g.CONFIG.with_(n_layers=12, d_model=768, n_heads=12,
                             n_kv_heads=4, head_dim=64, d_ff=2048,
                             vocab_size=32000, dtype="float32", remat=False)
        registry_entry = g.ENTRY
        import dataclasses
        object.__setattr__  # (configs are frozen; use with_)
        g.ENTRY = dataclasses.replace(g.ENTRY, smoke=cfg)
        argv = ["--arch", "granite-8b", "--smoke", "--steps", str(args.steps),
                "--global-batch", "8", "--seq-len", "256"]
    else:
        argv = ["--arch", "granite-8b", "--smoke", "--steps", str(args.steps),
                "--global-batch", "8", "--seq-len", "64"]
    if args.ckpt_dir:
        argv += ["--ckpt-dir", args.ckpt_dir]
    train.main(argv)


if __name__ == "__main__":
    main()
