"""Streaming keyword spotting, end to end from the waveform.

1. Train KWT-Tiny from raw audio: synthetic chirp-keyword clips ->
   streaming MFCC frontend (repro.stream.features) -> KWT (paper §III,
   with audio standing in for the GSC recordings).
2. Run the always-on path on a continuous stream: ring-buffer incremental
   inference (repro.stream.engine) under a ``runtime.compile_model``
   engine (``--backend float|lut_float|lut|pallas``) + posterior
   smoothing / hysteresis triggering (repro.stream.detector).
3. Print detected keyword events vs the ground-truth event intervals.

Run:  PYTHONPATH=src python examples/stream_kws.py [--train-steps 150]
          [--backend lut]
Exits non-zero if the detector misses every keyword (CI smoke contract).
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import runtime
from repro.configs import registry
from repro.data import pipeline
from repro.launch.stream_serve import train_params
from repro.stream import detector as det
from repro.stream import engine
from repro.stream import features


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--stream-hops", type=int, default=400,
                    help="stream length (hops of 10ms)")
    ap.add_argument("--chunk-hops", type=int, default=2)
    ap.add_argument("--backend", default="float",
                    choices=runtime.available_backends())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    base_cfg = registry.get("kwt-tiny").config
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()
    t = engine.window_frames(base_cfg)
    print(f"KWT-Tiny streaming: window {t} frames = "
          f"{fcfg.receptive_field(t)/fcfg.sample_rate*1e3:.0f}ms, "
          f"hop {fcfg.hop_len/fcfg.sample_rate*1e3:.0f}ms")

    fparams = train_params(base_cfg, fcfg, args.train_steps, args.seed)
    eng = runtime.compile_model(base_cfg, fparams, backend=args.backend)
    print(eng.describe())
    cfg, params = eng.exec_cfg, eng.params

    audio, truth = pipeline.keyword_event_stream(
        args.seed + 1, 0, n_hops=args.stream_hops, hop_len=fcfg.hop_len)
    print(f"stream: {len(audio)/fcfg.sample_rate:.1f}s, "
          f"{len(truth)} keyword occurrences at hops {truth}")

    k = args.chunk_hops
    chunk_samples = k * fcfg.hop_len
    state = engine.init_stream_state(cfg, fcfg, 1)
    dstate = det.detector_init(dcfg, 1)

    @jax.jit
    def step(params, state, dstate, chunk):
        state, logits = engine.stream_step(params, state, chunk, cfg, fcfg)
        dstate, events = det.detector_step(
            dstate, engine.posteriors(logits), dcfg, warm=engine.warm(state))
        return state, dstate, events

    fired = []
    for h in range(0, args.stream_hops, k):
        chunk = jnp.asarray(audio[None, h*fcfg.hop_len:
                                  h*fcfg.hop_len + chunk_samples])
        state, dstate, events = step(params, state, dstate, chunk)
        if bool(events["fired"][0]):
            hop = h + k
            fired.append(hop)
            print(f"[event] keyword @ {det.event_time_s(hop, fcfg):.2f}s "
                  f"(hop {hop}, score {float(events['score'][0]):.2f})")

    hits = sum(1 for (s, e) in truth
               if any(s <= f <= e + dcfg.smooth_hops for f in fired))
    print(f"detected {len(fired)} events; {hits}/{len(truth)} keywords hit")
    if truth and hits == 0:
        print("FAIL: detector missed every keyword", file=sys.stderr)
        return 1
    print("streaming demo complete.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
