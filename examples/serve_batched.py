"""Batched serving of a small LM with continuous batching and the paper's
quantised+LUT path — compares float vs quantised throughput and outputs.

  PYTHONPATH=src python examples/serve_batched.py [--arch internlm2-1.8b]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    args = ap.parse_args()
    base = ["--arch", args.arch, "--smoke", "--requests", "8",
            "--slots", "4", "--max-len", "48"]
    print("== float path ==")
    serve.main(base)
    print("== quantised + LUT path (paper §IV+§VI) ==")
    serve.main(base + ["--backend", "lut_float"])


if __name__ == "__main__":
    main()
