"""Table V reproduction CLI: sweep power-of-2 scale factors for any arch.

For KWT-Tiny this reproduces the paper's sweep; for the assigned LM archs
(reduced configs on CPU) it demonstrates the technique is arch-generic:

  PYTHONPATH=src python examples/quantize_eval.py --arch kwt-tiny
  PYTHONPATH=src python examples/quantize_eval.py --arch internlm2-1.8b
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro import runtime
from repro.configs import registry
from repro.core import calibrate
from repro.data import pipeline
from repro.models import kwt
from repro.models import transformer as T
from repro.optim import adamw

PAIRS = [(3, 3), (4, 4), (5, 5), (6, 5), (6, 6)]   # Table V rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kwt-tiny")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    entry = registry.get(args.arch)

    if args.arch.startswith("kwt"):
        cfg = entry.config
        hp = adamw.HParams(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                           weight_decay=0.0)
        params = kwt.init_params(cfg, jax.random.PRNGKey(0))
        state = adamw.init(params, hp)

        @jax.jit
        def step(params, state, batch):
            loss, grads = jax.value_and_grad(kwt.loss_fn)(params, batch, cfg)
            params, state, _ = adamw.update(grads, state, params, hp,
                                            scan_stacked=False)
            return params, state, loss

        for i in range(args.steps):
            params, state, _ = step(params, state, pipeline.keyword_batch(
                0, i, batch=64, input_dim=cfg.input_dim,
                n_classes=cfg.n_classes))
        batches = [(b["mfcc"], b["labels"]) for b in pipeline.gsc_eval_set(
            0, n=512, input_dim=cfg.input_dim, n_classes=cfg.n_classes)]
        res = calibrate.sweep_scale_factors(
            lambda p, x: kwt.forward(p, x, cfg), params, batches, pairs=PAIRS)
        print("weights, inputs, accuracy, int8 bytes   (paper Table V)")
        for r in res:
            print(f"2^{r.weight_exponent} ({2**r.weight_exponent:3d}), "
                  f"2^{r.input_exponent} ({2**r.input_exponent:3d}), "
                  f"{r.accuracy:.3f}, {r.quantized_bytes}")
        return

    # LM arch: perplexity degradation per weight exponent (reduced config)
    cfg = entry.smoke
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = pipeline.lm_batch(0, 0, global_batch=4, seq_len=32,
                              vocab_size=cfg.vocab_size)
    ref_loss = float(T.loss_fn(params, batch, cfg))
    print(f"{args.arch}: float loss {ref_loss:.4f}")
    for wexp in (3, 4, 5, 6, 7):
        eng = runtime.compile_model(
            cfg, params, backend="lut_float",
            recipe=runtime.QuantRecipe.from_config(cfg, weight_exponent=wexp))
        l = float(T.loss_fn(eng.params, batch, eng.exec_cfg))
        print(f"  w=2^{wexp}: quantised+LUT loss {l:.4f} "
              f"(delta {l-ref_loss:+.4f})")


if __name__ == "__main__":
    main()
