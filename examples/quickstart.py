"""Quickstart: the paper's full journey on KWT-Tiny, end to end.

1. Train KWT-Tiny (1646 params — Table IV) on the synthetic 2-class GSC
   surrogate ("dog"/"notdog", paper §III).
2. Post-training power-of-2 quantisation at the Table V best exponents
   (weights 2^6, inputs 2^5) — ``runtime.QuantRecipe`` on the float backend.
3. The "+Hardware" path: the selected ``--backend`` (default ``lut`` =
   Q8.24 LUT softmax + LUT GELU; ``pallas`` = the same pipeline as Pallas
   kernels) via ``runtime.compile_model``.
Prints the Table IX accuracy staircase.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 300]
          [--backend lut|pallas|lut_float|float] [--eval-n 512]
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import runtime
from repro.configs import registry
from repro.data import pipeline
from repro.models import kwt
from repro.optim import adamw


def accuracy(eng, n=512):
    correct = total = 0
    for b in pipeline.gsc_eval_set(0, n=n, input_dim=eng.cfg.input_dim):
        pred = jnp.argmax(eng.forward(b["mfcc"]), -1)
        correct += int(jnp.sum(pred == b["labels"]))
        total += int(b["labels"].size)
    return correct / total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--backend", default="lut",
                    choices=runtime.available_backends(),
                    help="stage-3 execution backend")
    ap.add_argument("--eval-n", type=int, default=512)
    args = ap.parse_args()

    cfg = registry.get("kwt-tiny").config
    print(f"KWT-Tiny: {cfg.n_layers} layer, DIM={cfg.d_model}, "
          f"MLP_DIM={cfg.d_ff}, SEQLEN={cfg.input_dim[1]+1}")
    hp = adamw.HParams(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                       weight_decay=0.0)
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    print(f"parameters: {kwt.count_params(params)} (paper Table IV: 1646)")
    state = adamw.init(params, hp)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(kwt.loss_fn)(params, batch, cfg)
        params, state, m = adamw.update(grads, state, params, hp,
                                        scan_stacked=False)
        return params, state, loss

    for i in range(args.steps):
        batch = pipeline.keyword_batch(0, i, batch=64, input_dim=cfg.input_dim)
        params, state, loss = step(params, state, batch)
        if i % 50 == 0:
            print(f"step {i:4d}  loss {float(loss):.4f}")

    eng_f = runtime.compile_model(cfg, params, backend="float")
    acc = accuracy(eng_f, args.eval_n)
    print(f"\n[1] float32 accuracy:            {acc:.3f}")

    # stage 2: PTQ weights, still exact float ops (Table IX middle column)
    eng_q = runtime.compile_model(cfg, params, backend="float",
                                  recipe=runtime.QuantRecipe.from_config(cfg))
    acc_q = accuracy(eng_q, args.eval_n)
    print(f"[2] int8 PTQ (w=2^6, Table V):   {acc_q:.3f}  "
          f"({eng_q.rom_bytes} packed int8 ROM bytes — paper: 1.65 kB "
          "incl. its int8 rank-1 params)")

    # stage 3: the accelerated path under the selected backend
    eng_h = runtime.compile_model(cfg, params, backend=args.backend)
    acc_h = accuracy(eng_h, args.eval_n)
    print(f"[3] {eng_h.describe()}")
    print(f"    accuracy:                    {acc_h:.3f}  "
          "(paper Table IX: ~0.80 vs 0.872 float)")


if __name__ == "__main__":
    main()
