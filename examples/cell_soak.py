"""Cell soak: lane churn + in-flight QAT-artifact hot-swap, zero drops.

The CI smoke for ``repro.cell`` (README §repro.cell).  One process plays
the whole fleet lifecycle:

1. train a float KWT-Tiny briefly, QAT fine-tune, and EXPORT the packed
   int8 artifact (``repro.qat.export``) — the serving cell boots on it
   (``lut`` backend, integer-resident weights);
2. serve ``--streams`` synthetic keyword streams of random lengths
   through a ``ServeCell`` with fewer lanes than streams, so lanes churn
   (join/evict mid-run) the whole time;
3. one third of the way in, QAT fine-tunes a few MORE steps and
   publishes the fresh export through ``checkpoint.manager`` into the
   cell's watch directory; the cell's watcher picks it up mid-traffic
   and hot-swaps it behind the probe-parity gate;
4. exit non-zero unless: the swap happened (generation bumped), post-swap
   probe logits are bit-identical to a fresh same-flavour plan of the
   swapped artifact and inside the activation-quant envelope of its
   dequantise-first reference, every admitted stream ran to completion, and
   the ingested-hop ledger reconciles EXACTLY with the offered source
   hops (``cell_hops_total`` == sum of stream lengths, zero drops across
   churn and the swap).

Run:  PYTHONPATH=src python examples/cell_soak.py [--streams 10]
          [--slots 4] [--telemetry-out soak_trace.json]
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import cell as cellmod
from repro import qat, runtime, telemetry
from repro.checkpoint import manager
from repro.configs import registry
from repro.data import pipeline
from repro.launch import serve_common
from repro.launch.stream_serve import train_params
from repro.stream import detector as det
from repro.stream import features


def qat_artifact(cfg, params, steps, seed):
    """A few QAT steps + export: the packed int8 deploy artifact."""
    spec = qat.QATSpec(recipe=runtime.QuantRecipe.from_config(cfg))
    params, qstate = qat.finetune_qat(cfg, params, spec, steps, seed=seed)
    return qat.export(params, spec, qstate), params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--hops", type=int, default=40,
                    help="mean stream length in hops")
    ap.add_argument("--train-steps", type=int, default=25)
    ap.add_argument("--qat-steps", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    serve_common.add_telemetry_args(ap)
    args = ap.parse_args()

    cfg = registry.get("kwt-tiny").smoke
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()

    # [1] train + QAT-export the boot artifact; the cell serves the packed
    # tree integer-resident on the lut backend
    fparams = train_params(cfg, fcfg, args.train_steps, args.seed)
    ex1, fparams = qat_artifact(cfg, fparams, args.qat_steps, args.seed)
    eng = runtime.compile_model(cfg, ex1.qparams, backend="lut")
    assert eng.int_resident, "soak must serve the packed artifact"
    telemetry.log("engine", plan=eng.describe())

    rng = np.random.RandomState(args.seed)
    sources = {}
    for sid in range(args.streams):
        hops = int(rng.randint(max(args.hops // 2, 2), args.hops * 2))
        audio, events = pipeline.keyword_event_stream(
            args.seed, sid, n_hops=hops, hop_len=fcfg.hop_len)
        sources[sid] = {"audio": audio, "hops": hops}
    offered_hops = sum(s["hops"] for s in sources.values())

    watch_dir = tempfile.mkdtemp(prefix="cell_soak_ckpt_")
    probe = np.zeros((1,) + tuple(cfg.input_dim), np.float32)
    publish_after = offered_hops // 3
    B = args.slots

    with serve_common.session(args.telemetry_out) as (tracer, met):
        cell = cellmod.ServeCell(
            eng, slots=B, registry=met,
            admission=cellmod.AdmissionConfig(max_queue=args.streams),
            watch_dir=watch_dir, watch_like=ex1.qparams,
            probe=jnp.asarray(probe))
        with cell:
            lanes = cell.stream_lanes(fcfg, dcfg)
            for sid in sources:
                assert cell.admission.offer(sid).admitted
            active = [None] * B
            offset = np.zeros(B, np.int64)
            done, published = [], False
            while len(done) < args.streams:
                swapped = cell.maybe_swap()
                if swapped:
                    telemetry.log("soak_swap",
                                  generation=cell.handle.generation,
                                  mid_serve_lanes=lanes.n_active)
                for lane in lanes.free_lanes():
                    sid = cell.admission.pop()
                    if sid is None:
                        break
                    lanes.join(lane)
                    active[lane], offset[lane] = sid, 0
                if not published and met.counter(
                        "cell_hops_total").value >= publish_after:
                    # [3] fresh QAT export published mid-traffic
                    ex2, _ = qat_artifact(cfg, fparams, args.qat_steps,
                                          args.seed + 1)
                    manager.save(watch_dir, 2, ex2.qparams)
                    published = True
                    telemetry.log("soak_publish", step=2,
                                  rom_bytes=ex2.quantized_bytes[0])
                cs = lanes.chunk_samples
                chunk = np.zeros((B, cs), np.float32)
                ingest = np.zeros(B, np.int64)
                for i in range(B):
                    sid = active[i]
                    if sid is None:
                        continue
                    a = sources[sid]["audio"]
                    end = sources[sid]["hops"] * fcfg.hop_len
                    n = int(min(cs, end - offset[i]))
                    chunk[i, :n] = a[offset[i]:offset[i] + n]
                    offset[i] += n
                    ingest[i] = n // fcfg.hop_len
                lanes.hop(chunk, ingest=ingest)
                for i in range(B):
                    sid = active[i]
                    if sid is not None and \
                            offset[i] >= sources[sid]["hops"] * fcfg.hop_len:
                        done.append(sid)
                        lanes.evict(i)
                        active[i] = None

            # [4] the acceptance ledger
            m = cell.metrics
            failures = []
            if cell.handle.generation != 1 or m.swaps.value != 1:
                failures.append(
                    f"expected exactly one hot-swap, got generation="
                    f"{cell.handle.generation} swaps={m.swaps.value}")
            if m.swap_failures.value:
                failures.append(f"{m.swap_failures.value} swaps rejected")
            got = np.asarray(cell.engine.forward(jnp.asarray(probe)))
            _, q2 = None, manager.restore(watch_dir, 2, ex1.qparams)
            # bitwise vs a fresh same-flavour plan of the swapped-in
            # artifact; the dequantise-first reference bounds the
            # int-exec activation-quant envelope (hotswap gate semantics)
            same = runtime.compile_model(cfg, q2, backend="lut")
            if not np.array_equal(got,
                                  np.asarray(same.forward(jnp.asarray(probe)))):
                failures.append("post-swap probe logits diverge from a "
                                "fresh compile of the swapped artifact")
            ref = runtime.compile_model(cfg, q2, backend="lut",
                                        integer_resident=False,
                                        integer_exec=False)
            err = float(np.max(np.abs(
                got - np.asarray(ref.forward(jnp.asarray(probe))))))
            if err > cellmod.hotswap._INT_EXEC_PROBE_TOL:
                failures.append("post-swap probe logits outside the "
                                f"activation-quant envelope ({err:.4f})")
            if int(m.hops.value) != offered_hops or m.dropped_hops.value:
                failures.append(
                    f"hop ledger: ingested {int(m.hops.value)} != offered "
                    f"{offered_hops} (dropped={m.dropped_hops.value})")
            if len(done) != args.streams or m.evictions.value != args.streams:
                failures.append(f"{len(done)}/{args.streams} streams done, "
                                f"{m.evictions.value} evictions")
        telemetry.log("soak_done", streams=args.streams,
                      hops=int(m.hops.value), swaps=int(m.swaps.value),
                      generation=cell.handle.generation,
                      failures=len(failures))
    for f in failures:
        print("FAIL:", f)
    if failures:
        sys.exit(1)
    print(f"cell soak OK: {args.streams} streams over {B} lanes, "
          f"{offered_hops} hops ingested with zero drops, one hot-swap "
          "mid-traffic with verified probe parity")


if __name__ == "__main__":
    main()
