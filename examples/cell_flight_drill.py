"""Flight-recorder drill: inject a deadline-shed spike, demand a dump.

The observability counterpart of ``examples/cell_soak.py``: instead of
proving the cell serves correctly under churn, this drill proves the
black box notices when it doesn't.  It runs a :class:`repro.cell
.ServeCell` with a :class:`repro.telemetry.FlightRecorder` riding
along, drives healthy traffic, then *injects an incident* — a burst of
offered streams whose queue wait blows a tight admission deadline, so
the controller sheds them in a spike — and asserts the recorder:

1. dumped exactly one post-mortem (one incident → one artifact, the
   armed/tripped edge, not one dump per hop),
2. with ``reason == "shed_spike"`` and the window's rejected-counter
   delta visible in the artifact,
3. whose stage attribution names a real stage of this hop program
   (featurise/embed/encode — static cost-model weights here, since
   cell hops are untraced in production),
4. and that the ring holds the last hops as a readable trace.

Exits non-zero if any of that fails — CI runs this as the
flight-recorder gate.

Usage:  PYTHONPATH=src python examples/cell_flight_drill.py [--hops 24]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro import cell as cellmod
from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.models import kwt
from repro.stream import detector as det
from repro.stream import features

SLOTS = 4
SPIKE = 6          # streams shed in the injected incident
DEADLINE_MS = 5.0  # admission queue-wait budget (tight, so the drill
                   # sheds in milliseconds instead of serving minutes)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hops", type=int, default=24,
                    help="healthy hops before and after the incident")
    ap.add_argument("--backend", default="lut")
    ap.add_argument("--dump-dir", default="flight_dumps")
    args = ap.parse_args(argv)

    cfg = registry.get("kwt-tiny").smoke
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    eng = runtime.compile_model(cfg, params, backend=args.backend)
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()

    cell = cellmod.ServeCell(
        eng, slots=SLOTS, registry=telemetry.Registry(),
        admission=cellmod.AdmissionConfig(deadline_ms=DEADLINE_MS),
        flight=telemetry.FlightConfig(capacity=64, shed_spike=SPIKE,
                                      dump_dir=args.dump_dir))
    rng = np.random.RandomState(0)
    failures = []

    def check(ok, msg):
        print(("ok  " if ok else "FAIL") + f" {msg}")
        if not ok:
            failures.append(msg)

    with cell:
        lanes = cell.stream_lanes(fcfg, dcfg)
        # healthy phase: admit through the front door, serve every lane
        for lane in range(SLOTS):
            assert cell.admission.offer(f"s{lane}").admitted
            assert cell.admission.pop() is not None
            lanes.join(lane)
        chunk = 0.1 * rng.randn(SLOTS, fcfg.hop_len).astype(np.float32)
        for _ in range(args.hops):
            lanes.hop(chunk)
        check(not cell.flight.dumps,
              f"healthy phase: {args.hops} hops, no dump")

        # the incident: a burst arrives while every lane is busy; the
        # queue waits blow the deadline and pop() sheds the whole burst
        for i in range(SPIKE):
            cell.admission.offer(f"burst{i}")
        time.sleep(3 * DEADLINE_MS / 1e3)
        while cell.admission.pop() is not None:
            pass                      # nothing survives the deadline
        shed = int(cell.metrics.rejected.value)
        check(shed >= SPIKE, f"injected spike: {shed} streams shed")

        # the next hop lands the spike inside the recorder's window
        for _ in range(4):
            lanes.hop(chunk)

    fr = cell.flight
    check(len(fr.dumps) == 1,
          f"one incident -> one dump (got {len(fr.dumps)})")
    if fr.dumps:
        with open(fr.dumps[0]) as f:
            art = json.load(f)
        att = art["attribution"]
        check(art["reason"] == "shed_spike",
              f"dump reason: {art['reason']}")
        check(art["admission"]["rejected_in_window"] >= SPIKE,
              f"window shed delta: {art['admission']['rejected_in_window']}")
        check(att["slowest_stage"] in ("featurise", "embed", "encode",
                                       "unpack"),
              f"slow hops attributed to stage {att['slowest_stage']!r} "
              f"({att['method']}: {att['stage_ms']})")
        check(art["window_hops"] > 0 and len(art["trace"])
              == art["window_hops"],
              f"trace holds the last {art['window_hops']} hops")
        check("git_commit" in art["provenance"],
              f"provenance: {art['provenance']['git_commit']}")
        print(f"post-mortem: {fr.dumps[0]}")

    if failures:
        print(f"\nFLIGHT DRILL FAILED ({len(failures)}):", file=sys.stderr)
        for m in failures:
            print(f"  - {m}", file=sys.stderr)
        return 1
    print("\nflight drill passed: shed spike detected, dumped once, "
          "attributed to a named stage.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
