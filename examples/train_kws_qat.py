"""QAT end to end: train exactly the model the Engine deploys.

The paper's accuracy-recovery half (§III retraining + §IV quantisation)
as one pipeline on KWT-Tiny:

1. Train the float baseline (paper Table IV, 1646 params).
2. PTQ it (Table V best recipe) — the accuracy the old pipeline shipped.
3. QAT fine-tune (repro.qat): eq-9 fake-quant weights + Q8.24 LUT
   softmax/GELU in the loss forward, float shadow weights under AdamW.
4. Optionally distill from a float KWT-1 teacher while quantising
   (--distill; 35->2 head reduction + ablation-driven depth shrink).
5. Export (repro.qat.export) and verify the acceptance contract: QAT
   eval logits are BIT-IDENTICAL to the exported recipe on the ``lut``
   Engine, and (--check-backends) the exported params run the whole
   backend matrix.

Run:  PYTHONPATH=src python examples/train_kws_qat.py [--steps 300]
          [--qat-steps 200] [--distill] [--check-backends]
Exits non-zero if export parity fails or QAT ends below PTQ accuracy
(CI smoke contract).
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import qat, runtime
from repro.configs import registry
from repro.data import pipeline
from repro.models import kwt
from repro.qat import distill as D


def make_eval(cfg, exec_cfg, seed, n):
    """Param-tree accuracy on one eval fold (seed 0: test fold; other
    seeds: validation folds for checkpoint selection), jitted once."""
    fwd = jax.jit(lambda p, x: kwt.forward(p, x, exec_cfg))
    batches = pipeline.gsc_eval_set(seed, n=n, input_dim=cfg.input_dim)

    def acc(deployed_params):
        correct = total = 0
        for b in batches:
            pred = jnp.argmax(fwd(deployed_params, b["mfcc"]), -1)
            correct += int(jnp.sum(pred == b["labels"]))
            total += int(b["labels"].size)
        return correct / total

    return acc


def accuracy(eng, n=512):
    return make_eval(eng.cfg, eng.exec_cfg, 0, n)(eng.params)


def make_distill_spec(cfg, args):
    tcfg = D.teacher_config(registry.get("kwt-1").config, cfg)
    print("[distill] training float KWT-1 teacher on the student grid "
          f"({tcfg.n_layers} layers, {tcfg.n_classes} classes, "
          f"{args.teacher_steps} steps)")
    tparams = D.train_teacher(tcfg, args.teacher_steps, seed=args.seed + 1)
    if args.teacher_keep_layers and \
            args.teacher_keep_layers < tcfg.n_layers:
        cal = [pipeline.keyword_batch(args.seed + 2, i, batch=64,
                                      input_dim=tcfg.input_dim,
                                      n_classes=tcfg.n_classes)
               for i in range(2)]
        tparams, tcfg = D.shrink_teacher(tparams, tcfg,
                                         args.teacher_keep_layers, cal)
        # the paper's §III loop is remove-THEN-RETRAIN: a chopped
        # post-norm stack needs the retrain half before it can teach
        tparams = D.train_teacher(tcfg, args.teacher_steps,
                                  seed=args.seed + 1,
                                  init_params=tparams)
        print(f"[distill] surgeon shrink -> {tcfg.n_layers} highest-impact "
              "teacher blocks (+retrain)")
    tparams = D.reduce_head(tparams)
    print(f"[distill] head reduced {registry.get('kwt-1').config.n_classes}"
          f" -> {cfg.n_classes} classes")
    return D.DistillSpec(tparams, tcfg.with_(n_classes=cfg.n_classes),
                         alpha=args.distill_alpha,
                         temperature=args.distill_temp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="float baseline training steps")
    ap.add_argument("--qat-steps", type=int, default=None,
                    help="QAT fine-tune steps (default: --steps)")
    ap.add_argument("--distill", action="store_true",
                    help="KD from a float KWT-1 teacher during QAT")
    ap.add_argument("--teacher-steps", type=int, default=200)
    ap.add_argument("--teacher-keep-layers", type=int, default=4,
                    help="surgeon depth-shrink of the teacher (0: keep all)")
    ap.add_argument("--distill-alpha", type=float, default=0.5)
    ap.add_argument("--distill-temp", type=float, default=2.0)
    ap.add_argument("--qat-backend", default="lut")
    ap.add_argument("--bits", type=int, default=8, choices=(4, 8),
                    help="stored weight width: 8 -> int8, 4 -> nibble-"
                         "packed int4 (half the ROM; exponent calibrated "
                         "to the 4-bit no-saturation bound)")
    ap.add_argument("--check-backends", action="store_true",
                    help="run the exported params across the full backend "
                         "matrix (float/lut_float/lut/pallas)")
    ap.add_argument("--eval-n", type=int, default=512)
    ap.add_argument("--export-path", default=None,
                    help="write the int8 artifact + recipe JSON here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    qat_steps = args.qat_steps if args.qat_steps is not None else args.steps

    cfg = registry.get("kwt-tiny").config
    print(f"KWT-Tiny QAT: {cfg.n_layers} layer, DIM={cfg.d_model}, "
          f"{kwt.count_params(kwt.init_params(cfg, jax.random.PRNGKey(0)))}"
          " params")

    # [1] float baseline (distill.train_teacher is the generic float kwt
    # training loop; on the student config it trains the 2-class task)
    fparams = D.train_teacher(cfg, args.steps, seed=args.seed, lr=3e-3)
    acc_f = accuracy(runtime.compile_model(cfg, fparams, backend="float"),
                     args.eval_n)
    print(f"\n[1] float32 accuracy:          {acc_f:.3f}")

    # [2] PTQ (the old pipeline's deployment) under the same backend the
    # QAT loss will train through (explicit recipe: PTQ even on backends
    # that don't quantise by default).  Sub-8-bit recipes calibrate the
    # weight exponent to the analytic no-saturation bound — Table V's 2^6
    # saturates nearly everything at a 4-bit grid.
    recipe = runtime.QuantRecipe.from_config(cfg, bits=args.bits)
    if args.bits < 8:
        recipe = recipe.calibrated(fparams)
    eng_ptq = runtime.compile_model(cfg, fparams, backend=args.qat_backend,
                                    recipe=recipe)
    acc_ptq = accuracy(eng_ptq, args.eval_n)
    print(f"[2] PTQ  {eng_ptq.describe()}")
    print(f"    accuracy:                  {acc_ptq:.3f}")

    # [3] QAT fine-tune (optionally distilled): best-checkpoint selection
    # on a validation fold — step 0 IS the PTQ model, so the selected
    # export never regresses below PTQ on the selection fold
    spec = qat.QATSpec(
        recipe,
        qat.QATConfig(backend=args.qat_backend),
        distill=make_distill_spec(cfg, args) if args.distill else None)
    qparams, qstate = qat.finetune_qat(
        cfg, fparams, spec, qat_steps, seed=args.seed,
        fine_classes=35 if args.distill else None,
        select_fn=make_eval(cfg, spec.exec_cfg(cfg), 5, 256))
    ex = qat.export(qparams, spec, qstate)
    eng_qat = runtime.compile_model(cfg, ex.params,
                                    backend=args.qat_backend,
                                    recipe=ex.recipe)
    acc_qat = accuracy(eng_qat, args.eval_n)
    tag = "QAT+KD" if args.distill else "QAT"
    print(f"[3] {tag}  {eng_qat.describe()}")
    print(f"    accuracy:                  {acc_qat:.3f}  "
          f"(PTQ {acc_ptq:.3f}, float {acc_f:.3f})")

    # [4] acceptance: QAT eval path == the exported engine under the
    # trained backend, bit for bit.  QAT eval fake-quantises weights but
    # keeps float activations, so the bitwise reference is the
    # NON-executing plan; the default int-exec deployment additionally
    # quantises activations (eq 9) and is checked to its envelope.
    x = jnp.concatenate([b["mfcc"] for b in
                         pipeline.gsc_eval_set(0, n=128,
                                               input_dim=cfg.input_dim)])
    ev = qat.eval_forward(cfg, spec, ex.recipe)(qparams, x)
    eng_ref = runtime.compile_model(cfg, ex.params,
                                    backend=args.qat_backend,
                                    recipe=ex.recipe, integer_exec=False)
    if not bool(jnp.array_equal(ev, eng_ref.forward(x))):
        print(f"FAIL: QAT eval logits != exported {args.qat_backend} "
              "engine", file=sys.stderr)
        return 1
    print("[4] export parity: QAT eval logits BIT-IDENTICAL to the "
          f"exported {args.qat_backend} engine (non-executing plan)")
    if eng_qat.int_exec:
        envelope = float(jnp.max(jnp.abs(ev - eng_qat.forward(x))))
        print(f"    int-exec deployment within {envelope:.4f} max-abs of "
              "the QAT eval logits (activation-quant envelope)")

    if args.check_backends:
        for b in runtime.available_backends():
            eng = runtime.compile_model(cfg, ex.params, backend=b,
                                        recipe=ex.recipe)
            print(f"    backend {b:10s}: accuracy "
                  f"{accuracy(eng, args.eval_n):.3f}  ({eng.describe()})")

    if args.export_path:
        from repro.qat.export import load as export_load
        from repro.qat.export import save as export_save
        export_save(args.export_path, ex)
        print(f"    wrote {args.export_path}.npz / .json "
              f"({ex.quantized_bytes[0]} packed int{args.bits} bytes)")
        # the packed artifact round-trips and deploys with no float
        # detour: loaded QTensor tree -> Engine, logits bit-identical
        lrecipe, lqparams = export_load(args.export_path, ex.qparams)
        eng_loaded = runtime.compile_model(cfg, lqparams,
                                           backend=args.qat_backend,
                                           recipe=lrecipe)
        if not bool(jnp.array_equal(eng_loaded.forward(x),
                                    eng_qat.forward(x))):
            print("FAIL: reloaded packed artifact != exported engine",
                  file=sys.stderr)
            return 1
        print("    reloaded packed artifact BIT-IDENTICAL to the "
              "exported engine")

    # smoke contract: the selected QAT export must not regress below PTQ
    # (selection fold guarantees >=; allow test-fold sampling noise)
    if acc_qat < acc_ptq - 0.02:
        print(f"FAIL: QAT accuracy {acc_qat:.3f} below PTQ {acc_ptq:.3f}",
              file=sys.stderr)
        return 1
    print("qat demo complete.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
