"""Config system: model configs, shape specs, quantisation flags, registry.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (exact published dims), ``SHAPES`` (the shape cells it runs,
with explicit skips), and ``smoke_config()`` (a reduced same-family config
for CPU smoke tests).  ``registry.get(name)`` resolves ``--arch`` flags.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """The paper's technique as a first-class serving feature (§IV, §VI)."""

    enabled: bool = True
    weight_exponent: int = 6      # Table V best row: weights 2^6
    input_exponent: int = 5       # Table V best row: inputs 2^5
    bits: int = 8                 # stored weight width; <=4 nibble-packs
    residual_bits: int = 16       # paper: INT16 intermediates
    softmax_mode: str = "lut"     # "exact" | "lut" | "lut_fixed"
    act_mode: str = "lut"         # LUT GELU / SiLU
    quantize_kv_cache: bool = False   # beyond-paper: int8 KV cache
    per_channel: Optional[bool] = None  # None: registry default (LM-scale
    #                                     families per-channel, kwt scalar)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | rwkv | hybrid | encdec | kwt
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # --- block flavour ---
    activation: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    bias: bool = False            # biases on all linears (whisper / KWT)
    qk_norm: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    post_norm: bool = False       # KWT/ViT-as-per-paper uses post-norm
    use_rope: bool = True         # False: learned/sinusoidal positions
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- SSM / RWKV / hybrid ---
    ssm_state: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    sliding_window: int = 0       # 0 -> full attention
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0
    # --- KWT (the paper's own model) ---
    input_dim: tuple = ()
    patch_dim: tuple = ()
    n_classes: int = 0
    # --- numerics / the paper's technique ---
    dtype: str = "bfloat16"
    # softmax_mode / act_approx / kernel_interpret are pinned by
    # repro.runtime backends at plan time (runtime.compile_model); no call
    # site outside repro/runtime should mutate them directly.
    softmax_mode: str = "exact"   # exact | lut | lut_fixed | pallas
    act_approx: str = "exact"     # exact | lut | pallas
    kernel_interpret: bool = True  # pallas modes: interpret vs Mosaic,
    #                                decided ONCE at plan time, not per call
    int_exec: bool = False        # integer-executing plan: linear layers
    #                               quantise activations (eq 9) and run the
    #                               stored int payload directly; pinned by
    #                               runtime.compile_model, never set by hand
    quant: Optional[QuantConfig] = None
    # --- compile / distribution knobs ---
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "xla"        # xla | flash_lut (kernels.ops.lut_attention;
    #                               pinned by runtime backends / compile_model)
    seq_shard_activations: bool = False   # Megatron-SP style (hillclimb lever)
    scores_dtype: str = "float32"  # "bfloat16": halve attention-score HBM traffic
    pure_fsdp: bool = False        # shard params over (data x model), no TP
    tp_only: bool = False          # TP-resident weights (inference)
    rwkv_head_pad: bool = False    # pad RWKV heads to a TP multiple (EP-style)
    rwkv_fused_proj: bool = False  # fuse r/k/v/g projections (1 psum not 4)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (TP divisibility + MXU lanes,
        Megatron-style).  Pad logits are masked to -inf in the head."""
        return -(-self.vocab_size // 128) * 128

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def subquadratic(self) -> bool:
        return self.family in ("rwkv",) or (
            self.family == "hybrid") or (self.sliding_window > 0)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ModelConfig
    shapes: tuple
    skips: dict                   # shape name -> reason (documented skips)
    smoke: ModelConfig
