"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, 2 shared + 64 routed experts top-6, fine-grained.
[arXiv:2401.06066]
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408,
    activation="silu", gated_mlp=True, norm="rmsnorm",
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        head_dim=16, d_ff=32, expert_d_ff=32, n_experts=8,
                        n_shared_experts=2, top_k=2, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
