"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code.  [arXiv:2405.04324]"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152,
    activation="silu", gated_mlp=True, norm="rmsnorm",
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
