"""whisper-large-v3 [audio]: 32L d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend (STUB).  [arXiv:2212.04356]

Per the assignment the conv frontend is stubbed: input_specs() provides
precomputed frame embeddings [B, 1500, 1280].  GELU MLPs (the paper's
LUT-GELU applies directly), LayerNorm, biases, sinusoidal positions.
long_500k is skipped (full attention).
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, enc_seq=1500,
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    activation="gelu", gated_mlp=False, bias=True, norm="layernorm",
    use_rope=False, tie_embeddings=True,
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, n_enc_layers=2, enc_seq=16, d_model=64,
                        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                        vocab_size=256, dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
