"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads.  [arXiv:2411.13676]

The attention half uses a 2048 sliding window (Hymba's local-attention
configuration), making long_500k runnable: O(1) mamba state + O(W) ring
KV cache.
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, conv_width=4, dt_rank=100, sliding_window=2048,
    activation="silu", gated_mlp=True, norm="rmsnorm",
)

SKIPS = {}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, ssm_state=8, dt_rank=8,
                        sliding_window=8, vocab_size=256, dtype="float32",
                        remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
