"""KWT-Tiny (the paper's model, Table III): 1 layer, DIM 12, 1 head,
DIM_HEAD 8, MLP_DIM 24, MFCC [16,26], SEQLEN 27, 2 classes, ~1.6k params."""
from repro.configs.base import ArchEntry, ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="kwt-tiny", family="kwt",
    n_layers=1, d_model=12, n_heads=1, n_kv_heads=1, head_dim=8,
    d_ff=24, vocab_size=0, n_classes=2,
    input_dim=(16, 26), patch_dim=(16, 1),
    activation="gelu", gated_mlp=False, bias=True, norm="layernorm",
    post_norm=True, use_rope=False, dtype="float32",
    remat=False, scan_layers=False,
    quant=QuantConfig(),            # Table V best: weights 2^6, inputs 2^5
)


def smoke_config():
    return CONFIG


ENTRY = ArchEntry(CONFIG, (), {}, smoke_config())
