"""--arch name resolution for launchers, tests, and benchmarks."""

from __future__ import annotations

import importlib

ARCHS = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "chameleon-34b": "chameleon_34b",
    "whisper-large-v3": "whisper_large_v3",
    "hymba-1.5b": "hymba_1_5b",
    "rwkv6-3b": "rwkv6_3b",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-8b": "granite_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "kwt-1": "kwt_1",
    "kwt-tiny": "kwt_tiny",
}

ASSIGNED = [k for k in ARCHS if not k.startswith("kwt")]


def get(name: str):
    """Return the ArchEntry for an --arch id."""
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.ENTRY


def all_entries():
    return {name: get(name) for name in ARCHS}
