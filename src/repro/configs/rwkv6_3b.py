"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— RWKV-6 "Finch", data-dependent decay.  [arXiv:2404.05892]

No softmax anywhere in time-mix: the paper's LUT-softmax is inapplicable
(DESIGN.md §Arch-applicability); sigmoid/ReLU^2 use the bounded-domain LUT
method and int8 PTQ applies to all projections.  All shapes runnable
(sub-quadratic; O(1) decode state).
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    gated_mlp=False, norm="layernorm", use_rope=False,
)

SKIPS = {}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                        head_dim=64, d_ff=128, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
