"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens.  [arXiv:2405.09818]

The modality frontend is a STUB per the assignment: the VQ tokenizer's
codes share the 65536-entry vocabulary, so inputs are plain token ids.
Chameleon uses qk-norm for training stability.
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536,
    activation="silu", gated_mlp=True, norm="rmsnorm", qk_norm=True,
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
