"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064,
    activation="silu", gated_mlp=True, norm="rmsnorm", qkv_bias=True,
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
