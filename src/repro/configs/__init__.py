from repro.configs import base, registry  # noqa: F401
from repro.configs.base import ArchEntry, ModelConfig, QuantConfig, ShapeSpec  # noqa: F401
