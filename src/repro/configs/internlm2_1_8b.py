"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA.  [arXiv:2403.17297]"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92544,
    activation="silu", gated_mlp=True, norm="rmsnorm",
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
