"""KWT-1 (Table I/III): 12 layers, DIM 64, 1 head, DIM_HEAD 64,
MLP_DIM 256, MFCC [40,98], SEQLEN 99, 35 classes, ~607k params."""
from repro.configs.base import ArchEntry, ModelConfig

CONFIG = ModelConfig(
    name="kwt-1", family="kwt",
    n_layers=12, d_model=64, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=256, vocab_size=0, n_classes=35,
    input_dim=(40, 98), patch_dim=(40, 1),
    activation="gelu", gated_mlp=False, bias=True, norm="layernorm",
    post_norm=True, use_rope=False, dtype="float32",
    remat=False, scan_layers=False,
)


def smoke_config():
    return CONFIG.with_(n_layers=2)


ENTRY = ArchEntry(CONFIG, (), {}, smoke_config())
