"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

NOTE: the assignment line says "MoE 40e top-8" while its bracket note says
"32 experts"; we follow the primary field (40 experts, top-8).
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, expert_d_ff=512,
    activation="silu", gated_mlp=True, norm="rmsnorm",
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=32, expert_d_ff=32, n_experts=8,
                        top_k=2, vocab_size=256, dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
