"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP.  [arXiv:2402.16819]

Largest assigned arch (~340B params): requires 2-D param sharding
(FSDP x TP) and int8 optimizer moments to fit a 256-chip v5e pod
(DESIGN.md §3).
"""
from repro.configs.base import ArchEntry, LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000,
    activation="sqrelu", gated_mlp=False, norm="layernorm",
)

SKIPS = {"long_500k": "full attention (quadratic); assigned only to "
                      "SSM/hybrid/linear-attn archs"}


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=256, vocab_size=256,
                        dtype="float32", remat=False)


ENTRY = ArchEntry(CONFIG, LM_SHAPES, SKIPS, smoke_config())
