"""Production mesh construction (assignment spec, DESIGN.md §3).

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import (see dryrun.py) and everything else sees the real device count.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples / CPU)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# v5e hardware constants for the roofline (assignment spec)
PEAK_FLOPS_BF16 = 197e12       # per chip
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9                 # B/s per chip
ICI_BW = 50e9                  # B/s per link
