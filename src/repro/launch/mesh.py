"""Production mesh construction (assignment spec, DESIGN.md §3).

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import (see dryrun.py) and everything else sees the real device count.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples / CPU)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# v5e hardware constants, re-exported from the shared machine model in
# repro.perf.roofline (V5E) so launch planning and the perf layer can
# never disagree on the chip envelope.  ICI is launch-specific (the
# two-ceiling roofline model has no interconnect term).
from repro.perf.roofline import (          # noqa: E402
    V5E_HBM_BW as HBM_BW,
    V5E_ICI_BW as ICI_BW,
    V5E_PEAK_FLOPS_BF16 as PEAK_FLOPS_BF16,
    V5E_PEAK_FLOPS_INT8 as PEAK_FLOPS_INT8,
)
