"""Step builders: per (architecture x shape) train / prefill / decode steps,
their input ShapeDtypeStructs, and their sharding trees.

This module is the glue between configs, models, optim and the mesh: the
launchers (train.py / serve.py) and the dry-run (dryrun.py) all build their
jitted programs here, so the lowered-and-compiled artifact in the dry-run
is exactly the program a real fleet would run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import mesh as meshlib
from repro.models import encdec as E
from repro.models import transformer as T
from repro.optim import adamw
from repro.telemetry import annotate

# grad-accumulation microbatch counts chosen so per-device activation
# checkpoints fit v5e HBM (derivation in DESIGN.md §3 memory table)
MICROBATCHES = {
    ("nemotron-4-340b", "train_4k"): 16,
    ("chameleon-34b", "train_4k"): 16,
    ("qwen2.5-14b", "train_4k"): 8,
    ("granite-8b", "train_4k"): 8,
    ("deepseek-moe-16b", "train_4k"): 8,
    ("granite-moe-3b-a800m", "train_4k"): 2,
    ("internlm2-1.8b", "train_4k"): 2,
    ("rwkv6-3b", "train_4k"): 4,
    ("hymba-1.5b", "train_4k"): 4,
    ("whisper-large-v3", "train_4k"): 4,
}

# archs whose train activations additionally shard the SEQUENCE dim over
# the TP axis (Megatron-SP style) — required to fit HBM at 96L x d=18432
SEQ_SHARD = {("nemotron-4-340b", "train_4k"), ("chameleon-34b", "train_4k"),
             ("nemotron-4-340b", "prefill_32k"), ("chameleon-34b", "prefill_32k")}


def seq_axis_for(cfg: ModelConfig, shape: ShapeSpec):
    return "model" if (cfg.name, shape.name) in SEQ_SHARD else None


# archs whose optimizer state must be int8 to fit a pod (DESIGN.md §3)
INT8_MOMENT_ARCHS = {"nemotron-4-340b", "deepseek-moe-16b", "chameleon-34b",
                     "qwen2.5-14b"}


def hparams_for(cfg: ModelConfig) -> adamw.HParams:
    return adamw.HParams(int8_moments=cfg.name in INT8_MOMENT_ARCHS)


def microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh=None) -> int:
    n = MICROBATCHES.get((cfg.name, shape.name), 1)
    if mesh is not None:
        # each microbatch must still split over every DP device
        dp_total = 1
        for a in meshlib.dp_axes(mesh):
            dp_total *= mesh.shape[a]
        n = max(1, min(n, shape.global_batch // dp_total))
        while shape.global_batch % (n * dp_total):
            n -= 1
    return n


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model inputs for one step of the given shape (no state/params)."""
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "encdec":
        frames = jax.ShapeDtypeStruct((gb, cfg.enc_seq, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((gb, s), i32),
                    "labels": jax.ShapeDtypeStruct((gb, s), i32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((gb, s), i32)}
        return {"token": jax.ShapeDtypeStruct((gb,), i32)}
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((gb, s), i32),
                "labels": jax.ShapeDtypeStruct((gb, s), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((gb, s), i32)}
    return {"token": jax.ShapeDtypeStruct((gb,), i32)}


def batch_pspec(cfg: ModelConfig, shape: ShapeSpec, dp) -> dict:
    bp = P(dp)
    b2 = P(dp, None)
    b3 = P(dp, None, None)
    if cfg.family == "encdec":
        if shape.kind == "train":
            return {"frames": b3, "tokens": b2, "labels": b2}
        if shape.kind == "prefill":
            return {"frames": b3, "tokens": b2}
        return {"token": bp}
    if shape.kind == "train":
        return {"tokens": b2, "labels": b2}
    if shape.kind == "prefill":
        return {"tokens": b2}
    return {"token": bp}


def dp_for(shape: ShapeSpec, mesh):
    """DP axes for this cell; None when the global batch cannot split
    across every DP device (e.g. long_500k's batch of 1 -> replicated)."""
    dp = meshlib.dp_axes(mesh)
    tot = 1
    for a in dp:
        tot *= mesh.shape[a]
    return dp if shape.global_batch % tot == 0 else None


def model_module(cfg: ModelConfig):
    if cfg.family == "kwt":
        from repro.models import kwt as K
        return K
    return E if cfg.family == "encdec" else T


def params_shape(cfg: ModelConfig):
    mod = model_module(cfg)
    return jax.eval_shape(lambda k: mod.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def param_pspecs(cfg: ModelConfig):
    return model_module(cfg).param_specs(cfg)


def decode_state_shape(cfg: ModelConfig, shape: ShapeSpec):
    mod = model_module(cfg)
    return jax.eval_shape(
        lambda: mod.init_decode_state(cfg, shape.global_batch, shape.seq_len))


def decode_state_pspecs(cfg: ModelConfig, dp, tp_size=16):
    return model_module(cfg).decode_state_specs(cfg, dp, tp_size)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def _loss(cfg):
    return model_module(cfg).loss_fn


def make_train_step(cfg: ModelConfig, shape: ShapeSpec, hp=None, n_micro=None,
                    sync_mesh=None, sync_per_channel=False, sync_bits=8,
                    qat=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation over ``n_micro`` microbatches via lax.scan;
    grads are averaged in f32, then one AdamW update.

    ``sync_mesh`` enables error-feedback gradient compression on the
    mesh's slow axis (``dist.compress.compressed_grad_sync``; the ROADMAP
    follow-up from the repro.dist PR): the step then threads the residual
    state — ``(params, opt_state, err, batch) -> (params, opt_state, err,
    metrics)`` with ``err`` from ``compress.init_error_state``.
    ``sync_per_channel`` selects per-channel payload scales; ``sync_bits``
    the wire width (4 -> nibble-packed payloads via the shared
    ``core.quant`` codec, half the int8 wire bytes).

    ``qat`` (a ``repro.qat.train.QATSpec``) switches the step to
    quantisation-aware training: the loss forward runs eq-9 fake-quant
    params under a runtime Backend's LUT modes while AdamW updates the
    float shadow weights; the step then additionally threads the QAT
    state — ``(params, opt_state, qstate, [err,] batch) -> (params,
    opt_state, qstate, [err,] metrics)`` with ``qstate`` from
    ``qat.init_qat_state``.  Composes with ``sync_mesh``.
    """
    if qat is not None:
        from repro.qat import train as qat_train
        return qat_train.make_qat_train_step(
            cfg, shape, hp=hp, n_micro=n_micro, sync_mesh=sync_mesh,
            sync_per_channel=sync_per_channel, sync_bits=sync_bits, qat=qat)
    hp = hp or hparams_for(cfg)
    n_micro = n_micro or microbatches(cfg, shape)
    loss_fn = _loss(cfg)

    def split_micro(batch):
        def f(x):
            b = x.shape[0]
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])
        return jax.tree.map(f, batch)

    def compute_grads(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_fn)(params, batch, cfg)
        micro = split_micro(batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(loss_fn)(params, mb, cfg)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / n_micro,
                acc, g)
            return acc, l

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, losses = jax.lax.scan(body, zeros, micro)
        return jnp.mean(losses), grads

    def finish(loss, grads, opt_state, params):
        # telemetry.annotate stages (jax.named_scope) name the grads /
        # grad_sync / optimizer regions in XLA profiles; metadata-only.
        with annotate("optimizer"):
            new_params, new_opt, metrics = adamw.update(
                grads, opt_state, params, hp, scan_stacked=cfg.scan_layers)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    if sync_mesh is None:
        def train_step(params, opt_state, batch):
            with annotate("grads"):
                loss, grads = compute_grads(params, batch)
            return finish(loss, grads, opt_state, params)
        return train_step

    from repro.dist import compress

    def train_step_synced(params, opt_state, err, batch):
        with annotate("grads"):
            loss, grads = compute_grads(params, batch)
        with annotate("grad_sync"):
            grads, err = compress.compressed_grad_sync(
                grads, err, sync_mesh, per_channel=sync_per_channel,
                bits=sync_bits)
        new_params, new_opt, metrics = finish(loss, grads, opt_state, params)
        return new_params, new_opt, err, metrics

    return train_step_synced


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec):
    mod = model_module(cfg)

    if cfg.family == "encdec":
        def step(params, state, batch):
            return mod.prefill(params, batch["frames"], batch["tokens"],
                               cfg, state)
        return step

    def step(params, state, batch):
        return mod.prefill(params, batch["tokens"], cfg, state)
    return step


def make_decode_step(cfg: ModelConfig, shape: ShapeSpec):
    mod = model_module(cfg)

    def step(params, state, batch):
        return mod.decode_step(params, batch["token"], cfg, state)
    return step


# ---------------------------------------------------------------------------
# Jitted + sharded program assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Program:
    """A fully-specified (fn, in_shardings, example_args) unit, ready to
    ``jax.jit(...).lower(*args)``."""
    name: str
    fn: Any
    args: tuple          # ShapeDtypeStructs (or arrays)
    shardings: tuple     # same-structure NamedSharding trees
    multiplier: float = 1.0   # dry-run cost multiplier (DESIGN.md §4)
    donate: tuple = ()
    seq_axis: str | None = None   # Megatron-SP activation sharding
    dp: Any = "auto"              # DP axes override (None = replicated batch)


def _named(mesh, tree):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_step_program(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Program:
    """The full (while-loop-containing) step: the deployable artifact whose
    compile + memory_analysis the dry-run must pass."""
    dp = dp_for(shape, mesh)
    batch_sds = input_specs(cfg, shape)
    batch_sh = _named(mesh, batch_pspec(cfg, shape, dp))
    p_sds = params_shape(cfg)
    p_sh = _named(mesh, param_pspecs(cfg))
    if shape.kind == "train":
        hp = hparams_for(cfg)
        opt_sds = jax.eval_shape(functools.partial(adamw.init, hp=hp), p_sds)
        opt_sh = _named(mesh, adamw.opt_state_specs(param_pspecs(cfg), hp))
        fn = make_train_step(cfg, shape, hp,
                             n_micro=microbatches(cfg, shape, mesh))
        return Program(f"{cfg.name}:{shape.name}:train", fn,
                       (p_sds, opt_sds, batch_sds), (p_sh, opt_sh, batch_sh),
                       donate=(0, 1), seq_axis=seq_axis_for(cfg, shape), dp=dp)
    state_sds = decode_state_shape(cfg, shape)
    state_sh = _named(mesh, decode_state_pspecs(cfg, dp,
                                                mesh.shape["model"]))
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, shape)
        return Program(f"{cfg.name}:{shape.name}:prefill", fn,
                       (p_sds, state_sds, batch_sds), (p_sh, state_sh, batch_sh),
                       donate=(1,), seq_axis=seq_axis_for(cfg, shape), dp=dp)
    fn = make_decode_step(cfg, shape)
    return Program(f"{cfg.name}:{shape.name}:decode", fn,
                   (p_sds, state_sds, batch_sds), (p_sh, state_sh, batch_sh),
                   donate=(1,), dp=dp)


def lower_program(prog: Program, mesh, seq_axis=None):
    from repro.dist import ctx
    seq_axis = seq_axis or prog.seq_axis
    dp = prog.dp if prog.dp != "auto" else meshlib.dp_axes(mesh)
    with mesh, ctx.mesh_context(dp, seq_axis):
        jitted = jax.jit(prog.fn, in_shardings=prog.shardings,
                         donate_argnums=prog.donate)
        return jitted.lower(*prog.args)


# ---------------------------------------------------------------------------
# Cost decomposition (DESIGN.md §4)
#
# XLA's cost_analysis counts a while-loop body ONCE, so the scanned-layer
# (and scanned-chunk) costs must be reconstructed from while-free component
# programs:   total = sum_i multiplier_i x cost(component_i).
#
# dense/moe/whisper:  outside(L=0) + L x block          (exact)
# rwkv:               outside + L x [c1 + (S/c - 1)(c2 - c1)]   (exact: every
#                     sub-block is linear in S at fixed chunk c)
# hybrid (hymba):     rwkv-style linear part + windowed-attention correction
#                     via standalone attention programs at full S (exact)
# ---------------------------------------------------------------------------

from repro.models import layers as L  # noqa: E402


def _block_sds(cfg):
    return jax.eval_shape(
        lambda k: T.block_params(cfg, k), jax.random.PRNGKey(0))


def _x_sds(cfg, tokens_b, s):
    return jax.ShapeDtypeStruct((tokens_b, s, cfg.d_model), jnp.dtype(cfg.dtype))


def _block_fwd_fn(cfg, s, *, train):
    """Single-block apply (or fwd+bwd when train) on [B,s,D]."""
    def fwd(bp, x):
        state = T._fresh_state(cfg, x.shape[0])
        y, _ = T.apply_block(bp, x, cfg, state, positions=jnp.arange(s))
        return y

    if not train:
        return fwd

    def loss(bp, x):
        return jnp.sum(fwd(bp, x).astype(jnp.float32))

    body = jax.checkpoint(loss) if cfg.remat else loss
    return jax.grad(body, argnums=(0, 1))


def _attn_only_fn(cfg, s, *, train):
    """Standalone windowed attention on [B,s,D] (hymba correction term)."""
    def fwd(ap, x):
        y, _ = L.apply_attention(ap, x, cfg, positions=jnp.arange(s))
        return y

    if not train:
        return fwd

    def loss(ap, x):
        return jnp.sum(fwd(ap, x).astype(jnp.float32))

    body = jax.checkpoint(loss) if cfg.remat else loss
    return jax.grad(body, argnums=(0, 1))


def _attn_sds(cfg):
    return jax.eval_shape(
        lambda k: L.attention_params(cfg, k), jax.random.PRNGKey(0))


def _decode_block_fn(cfg, shape):
    w = cfg.sliding_window
    def fn(bp, x, state, idx):
        if cfg.family == "hybrid":
            return T.apply_block(bp, x, cfg, state,
                                 positions=idx + jnp.arange(1),
                                 cache_index=jnp.mod(idx, w),
                                 kv_len_valid=jnp.minimum(idx + 1, w),
                                 ring=True)
        if cfg.family == "rwkv":
            return T.apply_block(bp, x, cfg, state, positions=None)
        return T.apply_block(bp, x, cfg, state,
                             positions=idx + jnp.arange(x.shape[1]),
                             cache_index=idx, kv_len_valid=idx + x.shape[1])
    return fn


def _per_layer_decode_state_sds(cfg, shape):
    mod = model_module(cfg)
    full = jax.eval_shape(
        lambda: mod.init_decode_state(cfg, shape.global_batch, shape.seq_len))
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), full["layers"])


def _per_layer_decode_state_spec(cfg, dp, tp_size=16):
    full = model_module(cfg).decode_state_specs(cfg, dp, tp_size)
    return jax.tree.map(lambda spec: P(*tuple(spec)[1:]), full["layers"],
                        is_leaf=lambda x: isinstance(x, P))


def cost_programs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> list:
    """While-free component programs + multipliers for this cell."""
    dp = dp_for(shape, mesh)
    progs = []
    for_dp = dp
    x_spec = _named(mesh, P(dp, None, None))
    gb, s = shape.global_batch, shape.seq_len
    c = 16  # recurrence chunk (rwkv.CHUNK == ssm.CHUNK == 16)

    if cfg.family == "encdec":
        progs.extend(_whisper_cost_programs(cfg, shape, mesh))
        return progs

    if shape.kind == "train":
        n_micro = microbatches(cfg, shape, mesh)
        mb = gb // n_micro
        hp = hparams_for(cfg)
        block_sh = _named(mesh, T.block_specs(cfg))
        if cfg.family in ("dense", "moe"):
            progs.append(Program(
                "block_fwdbwd", _block_fwd_fn(cfg, s, train=True),
                (_block_sds(cfg), _x_sds(cfg, mb, s)), (block_sh, x_spec),
                multiplier=cfg.n_layers * n_micro,
                seq_axis=seq_axis_for(cfg, shape)))
        else:
            f1 = _block_fwd_fn(cfg, c, train=True)
            f2 = _block_fwd_fn(cfg, 2 * c, train=True)
            # linear-in-S two-point: c1 + (S/c - 1)(c2 - c1), applied by the
            # dry-run combiner via paired multipliers.
            m_hi = (s // c - 1) * cfg.n_layers * n_micro
            m_lo = cfg.n_layers * n_micro - m_hi
            progs.append(Program("block_fwdbwd@c",
                                 f1, (_block_sds(cfg), _x_sds(cfg, mb, c)),
                                 (block_sh, x_spec), multiplier=m_lo))
            progs.append(Program("block_fwdbwd@2c",
                                 f2, (_block_sds(cfg), _x_sds(cfg, mb, 2 * c)),
                                 (block_sh, x_spec), multiplier=m_hi))
            if cfg.family == "hybrid":
                progs.extend(_hymba_attn_correction(
                    cfg, mesh, mb, s, c, cfg.n_layers * n_micro, train=True))
        cfg0 = cfg.with_(n_layers=0)
        mb_shape = dataclasses.replace(shape, global_batch=mb)
        outside = make_train_like_loss(cfg0)
        progs.append(Program(
            "outside_fwdbwd", outside,
            (params_shape(cfg0), input_specs(cfg0, mb_shape)),
            (_named(mesh, param_pspecs(cfg0)),
             _named(mesh, batch_pspec(cfg0, mb_shape, dp))),
            multiplier=n_micro))
        # optimizer update over the full parameter tree
        def opt_fn(params, opt_state, grads):
            return adamw.update(grads, opt_state, params, hp,
                                scan_stacked=cfg.scan_layers)
        p_sds = params_shape(cfg)
        g_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), p_sds)
        opt_sds = jax.eval_shape(functools.partial(adamw.init, hp=hp), p_sds)
        p_sh = _named(mesh, param_pspecs(cfg))
        g_sh = p_sh
        opt_sh = _named(mesh, adamw.opt_state_specs(param_pspecs(cfg), hp))
        progs.append(Program("optimizer", opt_fn, (p_sds, opt_sds, g_sds),
                             (p_sh, opt_sh, g_sh), multiplier=1.0))
        return progs

    # ---- inference cells ----
    sq = 1 if shape.is_decode else s
    state_sds = _per_layer_decode_state_sds(cfg, shape)
    state_sh = _named(mesh, _per_layer_decode_state_spec(
        cfg, dp, mesh.shape["model"]))
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    idx_sh = _named(mesh, P())
    del for_dp
    block_sh = _named(mesh, T.block_specs(cfg))
    if cfg.family in ("dense", "moe") or shape.is_decode:
        fn = _decode_block_fn(cfg, shape)
        progs.append(Program(
            "block_step", fn,
            (_block_sds(cfg), _x_sds(cfg, gb, sq), state_sds, idx_sds),
            (block_sh, x_spec, state_sh, idx_sh),
            multiplier=cfg.n_layers))
    else:
        # rwkv/hybrid prefill: two-point in S (state threads through)
        for nm, sc, mult in _two_point(cfg, s, c):
            def fn(bp, x, sc=sc):
                state = T._fresh_state(cfg, x.shape[0])
                y, _ = T.apply_block(bp, x, cfg, state,
                                     positions=jnp.arange(sc))
                return y
            progs.append(Program(nm, fn,
                                 (_block_sds(cfg), _x_sds(cfg, gb, sc)),
                                 (block_sh, x_spec), multiplier=mult))
        if cfg.family == "hybrid":
            progs.extend(_hymba_attn_correction(cfg, mesh, gb, s, c,
                                                cfg.n_layers, train=False))
    cfg0 = cfg.with_(n_layers=0)
    mod = model_module(cfg)

    def outside_fn(params, tokens):
        return mod.forward_no_blocks(params, tokens, cfg0)

    progs.append(Program(
        "outside", outside_fn,
        (params_shape(cfg0), jax.ShapeDtypeStruct((gb, sq), jnp.int32)),
        (_named(mesh, param_pspecs(cfg0)), _named(mesh, P(dp, None))),
        multiplier=1.0))
    for pr in progs:
        pr.dp = dp
    return progs


def _two_point(cfg, s, c):
    """total = L*[c1 + m*(c2 - c1)], m = S/c - 1  ->  coeffs L(1-m), L*m."""
    m = s // c - 1
    return [("block@c", c, cfg.n_layers * (1 - m)),
            ("block@2c", 2 * c, cfg.n_layers * m)]


def make_train_like_loss(cfg0):
    loss_fn = _loss(cfg0)

    def fn(params, batch):
        return jax.grad(lambda p: loss_fn(p, batch, cfg0))(params)
    return fn


def _hymba_attn_correction(cfg, mesh, b, s, c, layer_mult, *, train):
    """Exact windowed-attention term: + attn(full S), - linearised estimate
    (attn@c, attn@2c with the two-point multipliers, negated)."""
    dp = meshlib.dp_axes(mesh)
    x_spec = _named(mesh, P(dp, None, None))
    attn_sh = _named(mesh, L.attention_specs(cfg))
    m = s // c - 1
    out = [Program("attn_full", _attn_only_fn(cfg, s, train=train),
                   (_attn_sds(cfg), _x_sds(cfg, b, s)), (attn_sh, x_spec),
                   multiplier=layer_mult)]
    out.append(Program("attn@c(-)", _attn_only_fn(cfg, c, train=train),
                       (_attn_sds(cfg), _x_sds(cfg, b, c)), (attn_sh, x_spec),
                       multiplier=-float(layer_mult * (1 - m))))
    out.append(Program("attn@2c(-)", _attn_only_fn(cfg, 2 * c, train=train),
                       (_attn_sds(cfg), _x_sds(cfg, b, 2 * c)),
                       (attn_sh, x_spec), multiplier=-float(layer_mult * m)))
    return out


def _whisper_cost_programs(cfg, shape, mesh):
    dp = meshlib.dp_axes(mesh)
    x_spec = _named(mesh, P(dp, None, None))
    progs = []
    train = shape.kind == "train"
    n_micro = microbatches(cfg, shape, mesh) if train else 1
    gb = shape.global_batch
    mb = gb // n_micro
    sq = 1 if shape.is_decode else shape.seq_len

    enc_sh = _named(mesh, E.enc_block_specs(cfg))
    dec_sh = _named(mesh, E.dec_block_specs(cfg))
    enc_sds = jax.eval_shape(lambda k: E.enc_block_params(cfg, k),
                             jax.random.PRNGKey(0))
    dec_sds = jax.eval_shape(lambda k: E.dec_block_params(cfg, k),
                             jax.random.PRNGKey(0))

    def enc_fwd(bp, x):
        return E.apply_enc_block(bp, x, cfg)

    def dec_fwd(bp, x, memory):
        y, _ = E.apply_dec_block(bp, x, cfg,
                                 positions=jnp.arange(x.shape[1]),
                                 memory=memory)
        return y

    if train:
        def enc_fn(bp, x):
            f = lambda bp, x: jnp.sum(enc_fwd(bp, x).astype(jnp.float32))
            f = jax.checkpoint(f) if cfg.remat else f
            return jax.grad(f, argnums=(0, 1))(bp, x)

        def dec_fn(bp, x, memory):
            f = lambda bp, x, m: jnp.sum(dec_fwd(bp, x, m).astype(jnp.float32))
            f = jax.checkpoint(f) if cfg.remat else f
            return jax.grad(f, argnums=(0, 1, 2))(bp, x, memory)
    else:
        enc_fn, dec_fn = enc_fwd, dec_fwd

    if not shape.is_decode:
        progs.append(Program(
            "enc_block", enc_fn,
            (enc_sds, _x_sds(cfg, mb, cfg.enc_seq)), (enc_sh, x_spec),
            multiplier=cfg.n_enc_layers * n_micro))
        progs.append(Program(
            "dec_block", dec_fn,
            (dec_sds, _x_sds(cfg, mb, sq), _x_sds(cfg, mb, cfg.enc_seq)),
            (dec_sh, x_spec, x_spec), multiplier=cfg.n_layers * n_micro))
    else:
        state_sds = _per_layer_decode_state_sds(cfg, shape)
        state_sh = _named(mesh, _per_layer_decode_state_spec(
            cfg, meshlib.dp_axes(mesh), mesh.shape["model"]))
        idx_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def dec_step(bp, x, st, idx):
            return E.apply_dec_block(bp, x, cfg, positions=idx + jnp.arange(1),
                                     state=st, cache_index=idx)
        progs.append(Program(
            "dec_block_step", dec_step,
            (dec_sds, _x_sds(cfg, gb, 1), state_sds, idx_sds),
            (dec_sh, x_spec, state_sh, _named(mesh, P())),
            multiplier=cfg.n_layers))

    # outside: embed/head/loss with zero layers
    cfg0 = cfg.with_(n_layers=0, n_enc_layers=0)
    if train:
        mb_shape = dataclasses.replace(shape, global_batch=mb)
        progs.append(Program(
            "outside_fwdbwd", make_train_like_loss(cfg0),
            (params_shape(cfg0), input_specs(cfg0, mb_shape)),
            (_named(mesh, param_pspecs(cfg0)),
             _named(mesh, batch_pspec(cfg0, mb_shape, meshlib.dp_axes(mesh)))),
            multiplier=n_micro))
        hp = hparams_for(cfg)
        p_sds = params_shape(cfg)
        g_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), p_sds)
        opt_sds = jax.eval_shape(functools.partial(adamw.init, hp=hp), p_sds)
        p_sh = _named(mesh, param_pspecs(cfg))
        opt_sh = _named(mesh, adamw.opt_state_specs(param_pspecs(cfg), hp))

        def opt_fn(params, opt_state, grads):
            return adamw.update(grads, opt_state, params, hp,
                                scan_stacked=cfg.scan_layers)
        progs.append(Program("optimizer", opt_fn, (p_sds, opt_sds, g_sds),
                             (p_sh, opt_sh, p_sh), multiplier=1.0))
    return progs
