"""Shared serving telemetry plumbing for ``serve.py`` / ``stream_serve.py``.

Both servers grew the same observability boilerplate — a ``--telemetry-out``
flag, a per-run metrics :class:`~repro.telemetry.Registry`, and the
end-of-run artifact writes — so it lives here once.  (The other candidate
for deduplication, a "copy-pasted trainer", does not exist: ``serve.py``
serves LM checkpoints and has no trainer, and ``examples/stream_kws.py``
already imports ``stream_serve.train_params`` rather than copying it.)

``session(out_path)`` yields ``(tracer, registry)``:

* ``out_path=None`` — tracing stays disabled (the zero-cost fast path in
  every instrumented call site) and the registry is export-less scratch.
* ``out_path="trace.json"`` — spans record for the whole run; on exit the
  Chrome trace lands at ``trace.json`` with the Prometheus text + JSON
  metric exports as siblings (``trace.prom`` / ``trace.metrics.json``) —
  the layout ``python -m repro.telemetry`` validates in CI.
"""

from __future__ import annotations

import contextlib
import os

from repro import telemetry


def add_telemetry_args(ap) -> None:
    ap.add_argument("--telemetry-out", default=None, metavar="TRACE_JSON",
                    help="enable span tracing and write the Chrome trace "
                         "here, with .prom / .metrics.json metric exports "
                         "as siblings")


@contextlib.contextmanager
def session(out_path: str | None):
    registry = telemetry.Registry()
    tracer = telemetry.enable() if out_path else None
    try:
        yield tracer, registry
    finally:
        if out_path:
            telemetry.disable()
            tracer.save(out_path)
            prom, js = registry.save(os.path.splitext(out_path)[0])
            telemetry.log("telemetry_saved", trace=out_path,
                          events=len(tracer.events), prom=prom, metrics=js)
