"""Shared serving telemetry plumbing for ``serve.py`` / ``stream_serve.py``.

Both servers grew the same observability boilerplate — a ``--telemetry-out``
flag, a per-run metrics :class:`~repro.telemetry.Registry`, and the
end-of-run artifact writes — so it lives here once.  (The other candidate
for deduplication, a "copy-pasted trainer", does not exist: ``serve.py``
serves LM checkpoints and has no trainer, and ``examples/stream_kws.py``
already imports ``stream_serve.train_params`` rather than copying it.)

``session(out_path)`` yields ``(tracer, registry)``:

* ``out_path=None`` — tracing stays disabled (the zero-cost fast path in
  every instrumented call site) and the registry is export-less scratch.
* ``out_path="trace.json"`` — spans record for the whole run; on exit the
  Chrome trace lands at ``trace.json`` with the Prometheus text + JSON
  metric exports as siblings (``trace.prom`` / ``trace.metrics.json``) —
  the layout ``python -m repro.telemetry`` validates in CI.

The flush is crash-faithful: it runs on EVERY exit path — normal return,
exception, SIGINT (KeyboardInterrupt unwinds through the ``finally``) —
with SIGINT deferred for its duration so a second Ctrl-C cannot kill the
process mid-write, and each artifact saved independently so a failing
trace write still leaves the metric exports (and vice versa).  Aborted
runs are marked: the ``telemetry_saved`` log line carries
``aborted=<ExceptionType>`` so a soak harness reading partial artifacts
knows the run did not complete (tests/test_cell.py).
"""

from __future__ import annotations

import contextlib
import os
import signal
import threading

from repro import telemetry


def add_telemetry_args(ap) -> None:
    ap.add_argument("--telemetry-out", default=None, metavar="TRACE_JSON",
                    help="enable span tracing and write the Chrome trace "
                         "here, with .prom / .metrics.json metric exports "
                         "as siblings")


@contextlib.contextmanager
def _sigint_deferred():
    """Hold SIGINT for the duration of the artifact flush (main thread
    only — elsewhere signals don't deliver to us anyway)."""
    if threading.current_thread() is not threading.main_thread():
        yield
        return
    pending = []
    prev = signal.signal(signal.SIGINT,
                         lambda sig, frame: pending.append(sig))
    try:
        yield
    finally:
        signal.signal(signal.SIGINT, prev)
        if pending and callable(prev):
            prev(signal.SIGINT, None)


def _flush(tracer, registry, out_path: str, aborted: str | None) -> list:
    """Write trace + metric artifacts; each save isolated so one failure
    cannot eat the others.  Returns the save errors (tests inspect)."""
    errors = []
    with _sigint_deferred():
        telemetry.disable()
        try:
            tracer.save(out_path)
        except Exception as e:          # noqa: BLE001 - keep flushing
            errors.append(("trace", e))
        prom = js = None
        try:
            prom, js = registry.save(os.path.splitext(out_path)[0])
        except Exception as e:          # noqa: BLE001
            errors.append(("metrics", e))
        telemetry.log("telemetry_saved", trace=out_path,
                      events=len(tracer.events), prom=str(prom),
                      metrics=str(js), aborted=aborted or "",
                      save_errors=len(errors))
    return errors


@contextlib.contextmanager
def session(out_path: str | None):
    registry = telemetry.Registry()
    tracer = telemetry.enable() if out_path else None
    aborted = None
    try:
        yield tracer, registry
    except BaseException as e:          # mark, flush, re-raise
        aborted = type(e).__name__
        raise
    finally:
        if out_path:
            _flush(tracer, registry, out_path, aborted)
