"""Batched serving launcher (continuous-batching-lite).

A fixed pool of batch slots; each slot holds one request (prompt len,
target gen len).  Finished slots are immediately refilled from the queue —
the decode step always runs at full batch.  Prefill is chunked (hybrid
ring caches are filled window-aligned, <= Q_CHUNK tokens per chunk).

Execution policy is one flag: ``--backend float|lut_float|lut|pallas``
resolves through ``runtime.compile_model`` to an Engine that owns the
paper's pipeline end to end (power-of-2 PTQ weights + LUT softmax/GELU
for the quantising backends, Pallas kernels for ``pallas``), mirroring
the KWT-Tiny-Q (+Hardware) staircase at LM scale.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --requests 8 --max-len 64 [--backend lut]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.dist import ctx
from repro.launch import mesh as meshlib
from repro.launch import serve_common
from repro.launch import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--backend", default="float",
                    choices=runtime.available_backends(),
                    help="execution backend (runtime.compile_model); "
                         "the former --quantize flag is --backend lut_float")
    ap.add_argument("--seed", type=int, default=0)
    serve_common.add_telemetry_args(ap)
    args = ap.parse_args(argv)
    backend = args.backend

    entry = registry.get(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    mesh = meshlib.make_host_mesh()
    mod = steps.model_module(cfg)
    assert cfg.family != "encdec", "use whisper_serve example for enc-dec"

    rng = np.random.RandomState(args.seed)
    queue = [{"id": i,
              "prompt": rng.randint(0, cfg.vocab_size,
                                    size=rng.randint(4, args.max_len // 4)),
              "gen": int(rng.randint(4, args.max_len // 2))}
             for i in range(args.requests)]

    with serve_common.session(args.telemetry_out) as (tracer, met), \
            mesh, ctx.mesh_context(meshlib.dp_axes(mesh)):
        params = mod.init_params(cfg, jax.random.PRNGKey(args.seed))
        eng = runtime.compile_model(cfg, params, backend=backend)
        telemetry.log("engine", plan=eng.describe())

        prefill_ms = met.histogram("serve_prefill_latency_ms",
                                   "batched prompt prefill wall time",
                                   unit="ms")
        decode_ms = met.histogram("serve_decode_latency_ms",
                                  "decode step wall time", unit="ms")
        occupancy = met.gauge("serve_lane_occupancy",
                              "active slots / batch slots")
        qdepth = met.gauge("serve_queue_depth", "requests waiting for a slot")
        refill_ctr = met.counter("serve_lane_refills_total",
                                 "slot refill operations")
        tokens_ctr = met.counter("serve_tokens_total", "tokens decoded")

        B = args.slots
        state = eng.init_decode_state(B, args.max_len)

        # per-slot bookkeeping (host side)
        active = [None] * B
        remaining = np.zeros(B, np.int32)
        done, t0, decoded = [], time.time(), 0
        cur = jnp.zeros((B,), jnp.int32)

        while len(done) < args.requests:
            # refill empty slots -> batch prefill of their prompts together
            # (at most len(queue): free slots can outnumber waiting requests)
            refills = [i for i in range(B) if active[i] is None][:len(queue)]
            if refills:
                # pad prompts to common length, run one batched prefill
                reqs = [queue.pop(0) for _ in refills]
                plen = max(len(r["prompt"]) for r in reqs)
                toks = np.zeros((B, plen), np.int32)
                for i, r in zip(refills, reqs):
                    toks[i, -len(r["prompt"]):] = r["prompt"]
                    active[i] = r
                    remaining[i] = r["gen"]
                refill_ctr.inc(len(refills))
                state = eng.init_decode_state(B, args.max_len)
                t_pf = time.perf_counter()
                logits, state = eng.prefill(jnp.asarray(toks), state)
                logits = jax.block_until_ready(logits)
                prefill_ms.observe(1e3 * (time.perf_counter() - t_pf))
                cur = jnp.argmax(logits, -1).astype(jnp.int32)
            occupancy.set(sum(1 for a in active if a is not None) / B)
            qdepth.set(len(queue))
            t_dc = time.perf_counter()
            logits, state = eng.decode_step(cur, state)
            logits = jax.block_until_ready(logits)
            decode_ms.observe(1e3 * (time.perf_counter() - t_dc))
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            n_active = int(sum(1 for i in range(B) if active[i]))
            decoded += n_active
            tokens_ctr.inc(n_active)
            for i in range(B):
                if active[i] is None:
                    continue
                remaining[i] -= 1
                if remaining[i] <= 0:
                    done.append(active[i]["id"])
                    active[i] = None
        dt = time.time() - t0
        telemetry.log("serve_done", requests=args.requests, tokens=decoded,
                      wall_s=dt, tok_s=decoded / dt,
                      backend=eng.backend_name, **decode_ms.summary())


if __name__ == "__main__":
    main()
