"""Batched serving launcher (continuous-batching-lite).

A fixed pool of batch slots; each slot holds one request (prompt len,
target gen len).  Finished slots are immediately refilled from the queue —
the decode step always runs at full batch.  Prefill is chunked (hybrid
ring caches are filled window-aligned, <= Q_CHUNK tokens per chunk).

The paper's technique is a first-class serving flag: --quantize applies
power-of-2 PTQ (Table V exponents) to the weights and switches softmax /
activations to the LUT path, mirroring the KWT-Tiny-Q (+Hardware) pipeline
at LM scale.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --requests 8 --max-len 64 [--quantize]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import quant
from repro.dist import ctx
from repro.launch import mesh as meshlib
from repro.launch import steps
from repro.models import layers as L


def quantize_params(params, cfg, rounding="nearest"):
    """PTQ per paper §IV: int8 weights at 2^6, norms/biases stay float.
    ``rounding="floor"`` reproduces the eq-9 cast bit-exactly."""
    q = cfg.quant or __import__("repro.configs.base", fromlist=["QuantConfig"]).QuantConfig()
    qtree = quant.quantize_tree(params, weight_exponent=q.weight_exponent,
                                rounding=rounding)
    return quant.dequantize_tree(qtree)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--quantize", action="store_true",
                    help="paper technique: int8 PTQ weights + LUT softmax/act")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    entry = registry.get(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    if args.quantize:
        cfg = cfg.with_(softmax_mode="lut", act_approx="lut")
    mesh = meshlib.make_host_mesh()
    mod = steps.model_module(cfg)
    assert cfg.family != "encdec", "use whisper_serve example for enc-dec"

    rng = np.random.RandomState(args.seed)
    queue = [{"id": i,
              "prompt": rng.randint(0, cfg.vocab_size,
                                    size=rng.randint(4, args.max_len // 4)),
              "gen": int(rng.randint(4, args.max_len // 2))}
             for i in range(args.requests)]

    with mesh, ctx.mesh_context(meshlib.dp_axes(mesh)):
        params = mod.init_params(cfg, jax.random.PRNGKey(args.seed))
        if args.quantize:
            params = quantize_params(params, cfg)

        B = args.slots
        state = mod.init_decode_state(cfg, B, args.max_len)
        decode = jax.jit(lambda p, t, s: mod.decode_step(p, t, cfg, s))

        # per-slot bookkeeping (host side)
        active = [None] * B
        remaining = np.zeros(B, np.int32)
        done, t0, decoded = [], time.time(), 0
        cur = jnp.zeros((B,), jnp.int32)

        def prefill_one(slot, req, state):
            """Chunked prefill of one request into slot's cache lane."""
            # (single-request prefill via batch-1 state then splice would
            # need per-lane caches; for this driver we prefill at batch
            # granularity: restart all lanes when the pool refills.)
            return state

        while len(done) < args.requests:
            # refill empty slots -> batch prefill of their prompts together
            refills = [i for i in range(B) if active[i] is None and queue]
            if refills:
                # pad prompts to common length, run one batched prefill
                reqs = [queue.pop(0) for _ in refills]
                plen = max(len(r["prompt"]) for r in reqs)
                toks = np.zeros((B, plen), np.int32)
                for i, r in zip(refills, reqs):
                    toks[i, -len(r["prompt"]):] = r["prompt"]
                    active[i] = r
                    remaining[i] = r["gen"]
                state = mod.init_decode_state(cfg, B, args.max_len)
                logits, state = jax.jit(
                    lambda p, t, s: mod.prefill(p, t, cfg, s))(
                        params, jnp.asarray(toks), state)
                cur = jnp.argmax(logits, -1).astype(jnp.int32)
            logits, state = decode(params, cur, state)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            decoded += int(sum(1 for i in range(B) if active[i]))
            for i in range(B):
                if active[i] is None:
                    continue
                remaining[i] -= 1
                if remaining[i] <= 0:
                    done.append(active[i]["id"])
                    active[i] = None
        dt = time.time() - t0
        print(f"served {args.requests} requests, {decoded} tokens decoded "
              f"in {dt:.2f}s -> {decoded/dt:.1f} tok/s "
              f"(quantized={args.quantize})")


if __name__ == "__main__":
    main()
