"""Batched LM serving launcher: a thin CLI over ``repro.cell``.

Continuous batching proper (``cell.scheduler.LMScheduler``): a fixed
pool of batch slots where new requests prefill into free lanes WHILE
resident lanes keep decoding — per-lane decode depths, per-slot
EOS/evict, no drain barrier.  (The previous slot loop re-initialised the
whole decode state on every refill, wiping resident lanes' KV caches
mid-request; the scheduler's fresh-prefill + per-lane merge is the fix,
and tests/test_cell.py pins the resident-preservation property.)

Execution policy is one flag: ``--backend float|lut_float|lut|pallas``
resolves through ``runtime.compile_model`` to an Engine that owns the
paper's pipeline end to end (power-of-2 PTQ weights + LUT softmax/GELU
for the quantising backends, Pallas kernels for ``pallas``), mirroring
the KWT-Tiny-Q (+Hardware) staircase at LM scale.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --requests 8 --max-len 64 [--backend lut]
"""

from __future__ import annotations

import time

import argparse

import jax
import numpy as np

from repro import cell as cellmod
from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.launch import serve_common
from repro.launch import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--backend", default="float",
                    choices=runtime.available_backends(),
                    help="execution backend (runtime.compile_model); "
                         "the former --quantize flag is --backend lut_float")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="evict a lane early when it emits this token")
    ap.add_argument("--seed", type=int, default=0)
    serve_common.add_telemetry_args(ap)
    args = ap.parse_args(argv)

    entry = registry.get(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    mod = steps.model_module(cfg)
    assert cfg.family != "encdec", "use whisper_serve example for enc-dec"

    rng = np.random.RandomState(args.seed)
    requests = [{"id": i,
                 "prompt": rng.randint(0, cfg.vocab_size,
                                       size=rng.randint(4,
                                                        args.max_len // 4)),
                 "gen": int(rng.randint(4, args.max_len // 2))}
                for i in range(args.requests)]

    with serve_common.session(args.telemetry_out) as (tracer, met):
        params = mod.init_params(cfg, jax.random.PRNGKey(args.seed))
        eng = runtime.compile_model(cfg, params, backend=args.backend)
        telemetry.log("engine", plan=eng.describe())
        cell = cellmod.ServeCell(eng, slots=args.slots, registry=met)
        with cell:
            sched = cell.lm_scheduler(max_len=args.max_len,
                                      eos_id=args.eos_id)
            for r in requests:
                sched.submit(r["id"], r["prompt"], r["gen"])
            t0 = time.time()
            out = sched.run()
        dt = time.time() - t0
        decoded = sum(len(v) for v in out.values())
        telemetry.log("serve_done", requests=args.requests, tokens=decoded,
                      wall_s=dt, tok_s=decoded / dt,
                      backend=eng.backend_name,
                      **met.histogram("cell_decode_latency_ms").summary())
    return out


if __name__ == "__main__":
    main()
