import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this harness:
  1. builds the *deployable* step program (steps.build_step_program),
     lowers and compiles it against the production mesh, and records
     ``compiled.memory_analysis()``  -> proves the sharding fits HBM;
  2. (single-pod only) lowers the while-free cost-component programs
     (steps.cost_programs) and combines  sum_i  mult_i x cost_i  into HLO
     FLOPs / bytes / collective-bytes — the scan-aware accounting from
     DESIGN.md §4 (XLA cost_analysis counts while bodies once);
  3. derives the three roofline terms (compute / memory / collective) from
     v5e constants and writes everything to results/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force] [--list]
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import registry
from repro.launch import mesh as meshlib
from repro.launch import steps

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

_COLL_RE = re.compile(
    r"=\s+(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)[\s(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Result-shape bytes per collective kind (per device, per invocation).

    Documented proxy: the bytes of each collective's *result* shape — for
    all-reduce this equals the operand; for all-gather it is the gathered
    result (total data landed per device); for reduce-scatter the scattered
    shard.  Collectives inside while bodies appear once (hence the
    component decomposition).
    """
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        out[op] = out.get(op, 0) + _shape_bytes(m.group("type"))
    return out


_CONVERT_RE = re.compile(r"=\s+f32\[([0-9,]+)\][^=]*?\bconvert\(")


def cpu_convert_overhead(hlo_text: str, min_bytes: float = 2.5e8) -> int:
    """Bytes of large f32 copies of bf16 tensors created by XLA:CPU's
    bf16-dot lowering (converts hoisted out of while loops).  These do not
    exist on TPU (native bf16 MXU); subtracted to form the TPU-adjusted
    peak.  Counted once per distinct shape that (a) is produced by an f32
    convert, (b) also exists as a bf16 tensor, (c) exceeds min_bytes.
    """
    f32_shapes = set(_CONVERT_RE.findall(hlo_text))
    overhead = 0
    for dims in f32_shapes:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if 4 * n < min_bytes:
            continue
        if f"bf16[{dims}]" in hlo_text:
            overhead += 4 * n
    return overhead


def cost_of(compiled) -> dict:
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(sum(coll.values())),
        "collectives": coll,
    }


def combine(components: list) -> dict:
    tot = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    detail = []
    for name, mult, c in components:
        for k in tot:
            tot[k] += mult * c[k]
        detail.append({"name": name, "multiplier": mult, **c})
    tot["components"] = detail
    return tot


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (N_active for MoE), 2*N*tokens decode."""
    p_sds = steps.params_shape(cfg)
    total = active = 0
    flat = jax.tree_util.tree_flatten_with_path(p_sds)[0]
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = "/".join(str(k) for k in path)
        if cfg.family == "moe" and any(w in keys for w in
                                       ("w_gate", "w_up", "w_down")) \
                and "shared" not in keys and "blocks" in keys:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    n_eff = active
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    if shape.kind == "train":
        return 6.0 * n_eff * tokens
    return 2.0 * n_eff * tokens


def roofline(cost: dict, n_chips: int) -> dict:
    """cost_analysis numbers are per-device (verified), so terms divide by
    per-chip rates directly.  Thin wrapper over the shared
    :data:`repro.perf.roofline.V5E` machine model (plus the ICI
    collective term, which the two-ceiling model doesn't carry) —
    hillclimb and the dry-run records keep this schema."""
    from repro.perf import roofline as perf_roofline

    v5e = perf_roofline.V5E
    compute_s = cost["flops"] / v5e.peak_flops
    memory_s = cost["bytes"] / v5e.mem_bw
    coll_s = cost["collective_bytes"] / meshlib.ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant}


def run_cell(arch: str, shape, *, mesh_kind: str, force: bool = False,
             with_cost: bool = True, tag: str = "") -> dict:
    entry = registry.get(arch)
    cfg = entry.config
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fname = os.path.join(
        RESULTS_DIR, f"{arch}__{shape.name}__{mesh_kind}{tag}.json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)

    if shape.name in entry.skips:
        rec = {"arch": arch, "shape": shape.name, "mesh": mesh_kind,
               "skipped": entry.skips[shape.name]}
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = meshlib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    prog = steps.build_step_program(cfg, shape, mesh)
    lowered = steps.lower_program(prog, mesh)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print(compiled.memory_analysis())       # spec: proves it fits
    conv_overhead = cpu_convert_overhead(compiled.as_text())
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
        "cpu_convert_overhead_bytes": int(conv_overhead),
        "peak_bytes_tpu_adjusted": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes
                                       - conv_overhead),
    }
    full_cost = cost_of(compiled)
    print({k: v for k, v in full_cost.items() if k != "collectives"})
    rec = {
        "arch": arch, "shape": shape.name, "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "compile_s": round(time.time() - t0, 1),
        "memory": mem,
        "fits_hbm": mem["peak_bytes_tpu_adjusted"] <= 16e9,
        "fits_hbm_raw": mem["peak_bytes_est"] <= 16e9,
        "full_program_cost_raw": full_cost,   # while bodies counted once!
    }

    if with_cost and mesh_kind == "single":
        comps = []
        for cp in steps.cost_programs(cfg, shape, mesh):
            c = cost_of(steps.lower_program(cp, mesh).compile())
            comps.append((cp.name, cp.multiplier, c))
        cost = combine(comps)
        rec["cost"] = cost
        rec["model_flops"] = model_flops(cfg, shape)
        rec["model_to_hlo"] = (rec["model_flops"] / n_chips
                               / max(cost["flops"], 1.0))
        rec["roofline"] = roofline(cost, n_chips)
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else registry.ASSIGNED
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        entry = registry.get(arch)
        for shape in entry.shapes:
            if args.shape and shape.name != args.shape:
                continue
            for mk in meshes:
                label = f"{arch} x {shape.name} x {mk}"
                if args.list:
                    print(label, "(skip)" if shape.name in entry.skips else "")
                    continue
                print(f"=== {label} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind=mk,
                                   force=args.force,
                                   with_cost=not args.no_cost)
                    if "skipped" in rec:
                        print("  skipped:", rec["skipped"])
                    else:
                        print(
                            "  ok: peak/device = "
                            f"{rec['memory']['peak_bytes_est']/1e9:.2f} GB "
                            "(TPU-adj "
                            f"{rec['memory'].get('peak_bytes_tpu_adjusted', rec['memory']['peak_bytes_est'])/1e9:.2f})"
                            + (f", dominant={rec['roofline']['dominant']}"
                               if "roofline" in rec else ""))
                except Exception:
                    failures.append(label)
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()
