"""Fault-tolerant training launcher.

Demonstrates, at host scale (CPU devices) with the exact production code
paths (steps.make_train_step + sharded pjit + checkpoint manager):
  * deterministic stateless-seeded data (restart-exact resume),
  * periodic async checkpointing (atomic rename),
  * crash/preemption recovery: --fail-at-step N injects a failure; rerunning
    the same command resumes from the newest complete checkpoint,
  * straggler watchdog: EWMA step-time monitor flags slow steps (on a real
    fleet this feeds the reslicing controller),
  * elastic restart: --data/--model may differ across runs; restore
    re-shards against the new mesh.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import manager
from repro.configs import registry
from repro.configs.base import ShapeSpec
from repro.data import pipeline
from repro.dist import ctx
from repro.launch import mesh as meshlib
from repro.launch import steps


class StragglerMonitor:
    """EWMA step-time watchdog (DESIGN.md §3 fault-tolerance)."""

    def __init__(self, alpha=0.2, threshold=2.5):
        self.alpha, self.threshold = alpha, threshold
        self.ewma = None
        self.flagged = []

    def observe(self, step, dt):
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
            print(f"[straggler] step {step}: {dt*1e3:.1f}ms vs "
                  f"EWMA {self.ewma*1e3:.1f}ms -> would trigger reslicing")
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--data", type=int, default=1, help="mesh data axis")
    ap.add_argument("--model", type=int, default=1, help="mesh model axis")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash at this step (recovery demo)")
    ap.add_argument("--compressed-grads", action="store_true",
                    help="int8 error-feedback gradient sync on the mesh's "
                         "slow axis (dist.compress)")
    ap.add_argument("--per-channel-scales", action="store_true",
                    help="per-channel payload scales for --compressed-grads")
    ap.add_argument("--grad-bits", type=int, default=8, choices=(4, 8),
                    help="wire width for --compressed-grads payloads "
                         "(4: nibble-packed via the shared core.quant "
                         "codec, half the int8 wire bytes)")
    ap.add_argument("--qat", action="store_true",
                    help="quantisation-aware training: the loss forward "
                         "runs eq-9 fake-quant params under --qat-backend's "
                         "LUT modes (repro.qat)")
    ap.add_argument("--qat-backend", default="lut",
                    help="runtime backend whose numerics the QAT loss runs")
    ap.add_argument("--qat-start-step", type=int, default=0,
                    help="float warm-up steps before fake-quant activates")
    ap.add_argument("--qat-learn-exponent", action="store_true",
                    help="recalibrate the weight exponent from the shadow "
                         "weights until --qat-freeze-exponent-step")
    ap.add_argument("--qat-freeze-exponent-step", type=int, default=0,
                    help="freeze the learned exponent after this step "
                         "(0: keep recalibrating every step)")
    ap.add_argument("--distill-teacher-arch", default=None,
                    help="KWT only: float teacher arch for KD during QAT "
                         "(e.g. kwt-1; head is reduced to the student's "
                         "classes)")
    ap.add_argument("--distill-teacher-steps", type=int, default=200,
                    help="float training steps for the inline KD teacher")
    ap.add_argument("--distill-alpha", type=float, default=0.5)
    ap.add_argument("--distill-temp", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    entry = registry.get(args.arch)
    cfg = entry.smoke if args.smoke else entry.config
    shape = ShapeSpec("custom", args.seq_len, args.global_batch, "train")
    mesh = meshlib.make_host_mesh(args.data, args.model)
    dp = meshlib.dp_axes(mesh)
    hp = dataclasses.replace(steps.hparams_for(cfg), lr=1e-3,
                             warmup_steps=max(2, args.steps // 10),
                             total_steps=max(args.steps, 10))
    mod = steps.model_module(cfg)

    qat_spec = None
    fine_classes = None
    if args.qat:
        from repro import qat as qat_mod
        from repro.runtime import QuantRecipe
        distill = None
        if args.distill_teacher_arch:
            if cfg.family != "kwt":
                ap.error("--distill-teacher-arch is the KWT KD path "
                         "(paper §III); LM QAT runs without a teacher")
            from repro.qat import distill as distill_mod
            tcfg = distill_mod.teacher_config(
                registry.get(args.distill_teacher_arch).config, cfg)
            print(f"[distill] training float teacher {tcfg.name} "
                  f"({args.distill_teacher_steps} steps, "
                  f"{tcfg.n_classes} classes)")
            tparams = distill_mod.train_teacher(
                tcfg, args.distill_teacher_steps, seed=args.seed + 1)
            tparams = distill_mod.reduce_head(tparams)
            distill = distill_mod.DistillSpec(
                tparams, tcfg.with_(n_classes=cfg.n_classes),
                alpha=args.distill_alpha, temperature=args.distill_temp)
            # KD draws the fine-grained surrogate (coarsened to the
            # student's classes) so the teacher stays on-distribution
            fine_classes = tcfg.n_classes
        qat_spec = qat_mod.QATSpec(
            QuantRecipe.from_config(cfg),
            qat_mod.QATConfig(
                backend=args.qat_backend, start_step=args.qat_start_step,
                learn_exponent=args.qat_learn_exponent,
                freeze_exponent_step=args.qat_freeze_exponent_step),
            distill=distill)
        print(f"[qat] recipe {qat_spec.recipe} under backend="
              f"{args.qat_backend}")

    from jax.sharding import NamedSharding
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        steps.param_pspecs(cfg),
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))

    with mesh, ctx.mesh_context(dp):
        params = jax.jit(
            lambda k: mod.init_params(cfg, k),
            out_shardings=p_sh)(jax.random.PRNGKey(args.seed))
        from repro.optim import adamw
        opt_state = adamw.init(params, hp)

        from repro.dist import compress
        err = compress.init_error_state(params) if args.compressed_grads \
            else None
        qstate = None
        if qat_spec is not None:
            from repro import qat as qat_mod
            qstate = qat_mod.init_qat_state(qat_spec)

        start_step = 0
        if args.ckpt_dir:
            # resume from the newest step complete in EVERY tree: the opt
            # save is async, so a crash can leave params one step ahead;
            # with --compressed-grads the error-feedback residuals are a
            # third tree (dropping them would break the telescoping
            # drift bound at every restart), and --qat adds the QAT state
            # (float shadow weights are the params tree; the learned
            # exponent + step counter must restore with them or the
            # exported recipe would drift across restarts)
            cand = [manager.latest_step(args.ckpt_dir),
                    manager.latest_step(args.ckpt_dir + "/opt")]
            if args.compressed_grads:
                cand.append(manager.latest_step(args.ckpt_dir + "/err"))
            if qstate is not None:
                cand.append(manager.latest_step(args.ckpt_dir + "/qat"))
            if cand[0] is not None and any(c is None for c in cand[1:]):
                print(f"[restore] params checkpoint at step {cand[0]} has no "
                      "complete optimizer/error state — starting from step 0")
            latest = None if any(c is None for c in cand) else min(cand)
            if latest is not None:
                print(f"[restore] resuming from step {latest}")
                params = manager.restore(args.ckpt_dir, latest, params)
                opt_state = manager.restore(
                    args.ckpt_dir + "/opt", latest, opt_state)
                if args.compressed_grads:
                    err = manager.restore(
                        args.ckpt_dir + "/err", latest, err)
                if qstate is not None:
                    qstate = manager.restore(
                        args.ckpt_dir + "/qat", latest, qstate)
                start_step = latest

        sync_mesh = mesh if args.compressed_grads else None
        train_step = jax.jit(
            steps.make_train_step(cfg, shape, hp, n_micro=1,
                                  sync_mesh=sync_mesh,
                                  sync_per_channel=args.per_channel_scales,
                                  sync_bits=args.grad_bits,
                                  qat=qat_spec),
            donate_argnums=(0, 1))

        mon = StragglerMonitor()
        pending = None
        for step in range(start_step, args.steps):
            if step == args.fail_at_step:
                raise RuntimeError(
                    f"[injected failure] node lost at step {step} — rerun "
                    "the same command to recover from the last checkpoint")
            if cfg.family == "kwt":
                batch = pipeline.keyword_batch(
                    args.seed, step, batch=args.global_batch,
                    input_dim=cfg.input_dim,
                    n_classes=fine_classes or cfg.n_classes)
                if fine_classes:
                    batch = {"mfcc": batch["mfcc"],
                             "labels": batch["labels"] % cfg.n_classes}
            elif cfg.family == "encdec":
                batch = _whisper_batch(args, cfg, step)
            else:
                batch = pipeline.lm_batch(
                    args.seed, step, global_batch=args.global_batch,
                    seq_len=args.seq_len, vocab_size=cfg.vocab_size)
            t0 = time.time()
            if qstate is not None and args.compressed_grads:
                params, opt_state, qstate, err, metrics = train_step(
                    params, opt_state, qstate, err, batch)
            elif qstate is not None:
                params, opt_state, qstate, metrics = train_step(
                    params, opt_state, qstate, batch)
            elif args.compressed_grads:
                params, opt_state, err, metrics = train_step(
                    params, opt_state, err, batch)
            else:
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            mon.observe(step, dt)
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)
            assert np.isfinite(loss), "loss diverged"
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                manager.save(args.ckpt_dir, step + 1, params, blocking=True)
                if err is not None:
                    manager.save(args.ckpt_dir + "/err", step + 1, err,
                                 blocking=True)
                if qstate is not None:
                    manager.save(args.ckpt_dir + "/qat", step + 1, qstate,
                                 blocking=True)
                pending = manager.save(args.ckpt_dir + "/opt", step + 1,
                                       opt_state, blocking=False)
        if pending is not None:
            pending.join()
    if qat_spec is not None:
        from repro import qat as qat_mod
        ex = qat_mod.export(params, qat_spec, qstate)
        print(f"[qat] exported recipe: {ex.recipe}; packed int bytes "
              f"{ex.quantized_bytes[0]} + float {ex.quantized_bytes[1]}")
    print("training complete.")
    return params


def _whisper_batch(args, cfg, step):
    key = jax.random.fold_in(jax.random.PRNGKey(args.seed + 77), step)
    k1, k2 = jax.random.split(key)
    frames = jax.random.normal(
        k1, (args.global_batch, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(
        k2, (args.global_batch, args.seq_len + 1), 0, cfg.vocab_size)
    return {"frames": frames, "tokens": toks[:, :-1], "labels": toks[:, 1:]}


if __name__ == "__main__":
    main()
