"""Multi-stream streaming-KWS server (continuous-batching-lite for audio).

The streaming analogue of ``launch/serve.py``: a fixed pool of ``--slots``
batch lanes, each lane carrying one live audio stream.  Every hop, one
chunk per lane is packed into a single ``[B, k*hop]`` batch and pushed
through the jitted ``stream.engine.stream_step`` + ``stream.detector``
under ``dist.ctx`` sharding; finished streams free their lane, which is
zeroed (``engine.reset_lane``) and immediately refilled from the queue —
the step always runs at full batch.

Execution policy is the same first-class serving flag as offline serve:
``--backend float|lut_float|lut|pallas`` resolves through
``runtime.compile_model`` to an Engine (eq-9 PTQ weights + LUT / Pallas
softmax-GELU for the non-float backends); streaming logits stay
bit-identical to that engine's offline forward either way
(tests/test_stream.py, tests/test_runtime.py).

Usage (CPU, reduced):
  PYTHONPATH=src python -m repro.launch.stream_serve --streams 8 --slots 4 \
      --hops 120 [--backend lut] [--train-steps 80]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.data import pipeline
from repro.dist import ctx
from repro.launch import mesh as meshlib
from repro.launch import serve_common
from repro.models import kwt
from repro.stream import detector as det
from repro.stream import engine
from repro.stream import features


def train_params(cfg, fcfg, n_steps: int, seed: int):
    """Quick end-to-end training from raw audio (waveform -> MFCC -> KWT)
    through the canonical ``steps.make_train_step``, so served detections
    are meaningful; n_steps=0 returns random init."""
    params = kwt.init_params(cfg, jax.random.PRNGKey(seed))
    if n_steps <= 0:
        return params
    from repro.configs.base import ShapeSpec
    from repro.launch import steps
    from repro.optim import adamw
    hp = adamw.HParams(lr=3e-3, warmup_steps=max(2, n_steps // 10),
                       total_steps=n_steps, weight_decay=0.0)
    opt = adamw.init(params, hp)
    n = engine.window_frames(cfg) * fcfg.hop_len
    shape = ShapeSpec("stream_train", engine.window_frames(cfg), 64, "train")
    step = jax.jit(steps.make_train_step(cfg, shape, hp, n_micro=1))
    featurize = jax.jit(lambda a: features.mfcc(a, fcfg))

    log_every = max(1, n_steps // 8)
    for i in range(n_steps):
        raw = pipeline.keyword_audio_batch(seed, i, batch=64, n_samples=n)
        params, opt, m = step(params, opt, {"mfcc": featurize(raw["audio"]),
                                            "labels": raw["labels"]})
        if (i + 1) % log_every == 0 or i + 1 == n_steps:
            telemetry.log("train_step", step=i + 1, of=n_steps,
                          loss=float(m["loss"]), lr=float(m["lr"]),
                          grad_norm=float(m["grad_norm"]))
    telemetry.log("train_done", steps=n_steps, loss=float(m["loss"]),
                  source="audio-derived MFCC")
    return params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kwt-tiny")
    ap.add_argument("--streams", type=int, default=8,
                    help="total streams to serve")
    ap.add_argument("--slots", type=int, default=4, help="batch lanes")
    ap.add_argument("--hops", type=int, default=120,
                    help="mean stream length in hops")
    ap.add_argument("--chunk-hops", type=int, default=1,
                    help="hops ingested per engine step")
    ap.add_argument("--backend", default="float",
                    choices=runtime.available_backends(),
                    help="execution backend (runtime.compile_model); "
                         "the former --quantize flag is --backend lut_float")
    ap.add_argument("--train-steps", type=int, default=80,
                    help="0 = serve a randomly initialised model")
    ap.add_argument("--seed", type=int, default=0)
    serve_common.add_telemetry_args(ap)
    args = ap.parse_args(argv)
    backend = args.backend

    entry = registry.get(args.arch)
    base_cfg = entry.smoke
    assert base_cfg.family == "kwt", "streaming serve drives the KWT family"
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()
    mesh = meshlib.make_host_mesh()

    # training always runs the float path; the engine then owns PTQ + mode
    # selection for serving.  The fused server hop closes over the engine's
    # LIVE float view (integer-resident plans store packed QTensors; the
    # per-plan unpack runs once here), keeping the joint jit's model graph
    # identical to Engine.forward's — the bit-identity contract.
    fparams = train_params(base_cfg, fcfg, args.train_steps, args.seed)
    eng = runtime.compile_model(base_cfg, fparams, backend=backend)
    telemetry.log("engine", plan=eng.describe())
    cfg, params = eng.exec_cfg, eng.live_params()

    B, k = args.slots, args.chunk_hops
    chunk_samples = k * fcfg.hop_len
    queue = list(range(args.streams))
    rng = np.random.RandomState(args.seed)
    sources = {}
    for sid in queue:
        # whole chunks, at least one (wide --chunk-hops must not floor to 0)
        hops = max(k, int(rng.randint(args.hops // 2, args.hops * 2))
                   // k * k)
        audio, events = pipeline.keyword_event_stream(
            args.seed, sid, n_hops=hops, hop_len=fcfg.hop_len)
        sources[sid] = {"audio": audio, "events": events, "hops": hops}

    with serve_common.session(args.telemetry_out) as (tracer, met), \
            mesh, ctx.mesh_context(meshlib.dp_axes(mesh)):
        hop_ms = met.histogram("serve_hop_latency_ms",
                               "engine+detector step wall time", unit="ms")
        occupancy = met.gauge("serve_lane_occupancy",
                              "active lanes / batch slots")
        qdepth = met.gauge("serve_queue_depth", "streams waiting for a lane")
        refills = met.counter("serve_lane_refills_total",
                              "lane reset+refill operations")
        hops_ctr = met.counter("serve_hops_total", "hops ingested per lane")
        events_ctr = met.counter("serve_detector_events_total",
                                 "keyword detections fired")
        rtf = met.histogram("serve_stream_rtf", "per-stream real-time "
                            "factor (wall seconds / audio seconds; <1 is "
                            "faster than realtime)", unit="x")

        state = engine.init_stream_state(cfg, fcfg, B, keep_features=False)
        dstate = det.detector_init(dcfg, B)
        step = jax.jit(lambda p, s, ds, c: _joint_step(p, s, ds, c, cfg,
                                                       fcfg, dcfg))
        reset = jax.jit(lambda s, ds, lane: (
            engine.reset_lane(s, lane), det.detector_reset_lane(ds, lane)))

        active = [None] * B          # stream id per lane
        offset = np.zeros(B, np.int64)
        started = np.zeros(B, np.float64)      # lane fill wall time
        fired, done, hops_run = [], [], 0
        t0 = time.time()
        while len(done) < args.streams:
            with telemetry.span("refill"):
                for i in range(B):   # refill free lanes
                    if active[i] is None and queue:
                        active[i] = queue.pop(0)
                        offset[i] = 0
                        started[i] = time.time()
                        state, dstate = reset(state, dstate, i)
                        refills.inc()
            n_active = sum(1 for a in active if a is not None)
            occupancy.set(n_active / B)
            qdepth.set(len(queue))
            chunk = np.zeros((B, chunk_samples), np.float32)
            with telemetry.span("pack"):
                for i in range(B):
                    if active[i] is not None:
                        a = sources[active[i]]["audio"]
                        chunk[i] = a[offset[i]:offset[i] + chunk_samples]
                        offset[i] += chunk_samples
            t_hop = time.perf_counter()
            with telemetry.span("hop", {"backend": eng.backend_name}):
                state, dstate, events = step(params, state, dstate,
                                             jnp.asarray(chunk))
                # the loop syncs on events every hop anyway (fired_now
                # below); blocking here just moves the sync inside the
                # measured window.
                events = jax.block_until_ready(events)
            hop_ms.observe(1e3 * (time.perf_counter() - t_hop))
            hops_run += k
            hops_ctr.inc(k)
            fired_now = np.asarray(events["fired"])
            with telemetry.span("detector"):
                for i in range(B):
                    sid = active[i]
                    if sid is None:
                        continue
                    if fired_now[i]:
                        hop = int(offset[i] // fcfg.hop_len)
                        fired.append((sid, hop))
                        events_ctr.inc()
                        telemetry.log(
                            "detector_event", stream=sid,
                            t_s=det.event_time_s(hop, fcfg),
                            score=float(events["score"][i]),
                            backend=eng.backend_name)
                    if offset[i] >= sources[sid]["hops"] * fcfg.hop_len:
                        done.append(sid)
                        active[i] = None
                        audio_s_i = sources[sid]["hops"] \
                            * fcfg.hop_len / fcfg.sample_rate
                        rtf.observe((time.time() - started[i]) / audio_s_i)
        dt = time.time() - t0
        audio_s = sum(s["hops"] for s in sources.values()) \
            * fcfg.hop_len / fcfg.sample_rate
        truth = sum(len(s["events"]) for s in sources.values())
        telemetry.log("serve_done", streams=args.streams, audio_s=audio_s,
                      wall_s=dt, realtime_x=audio_s / dt, fired=len(fired),
                      keywords=truth, backend=eng.backend_name,
                      **hop_ms.summary())
    return fired


def _joint_step(params, state, dstate, chunk, cfg, fcfg, dcfg):
    """One fused server hop: engine + posteriors + detector."""
    state, logits = engine.stream_step(params, state, chunk, cfg, fcfg)
    dstate, events = det.detector_step(dstate, engine.posteriors(logits),
                                       dcfg, warm=engine.warm(state))
    return state, dstate, events


if __name__ == "__main__":
    main()
