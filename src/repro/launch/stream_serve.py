"""Multi-stream streaming-KWS server: a thin CLI over ``repro.cell``.

The lane pool, admission control, per-lane lifecycle, hop accounting and
checkpoint hot-swap all live in :class:`repro.cell.ServeCell`; this
launcher only builds the Engine, synthesises stream sources, and feeds
chunks.  Every hop, one chunk per lane is packed into a single
``[B, k*hop]`` batch and pushed through the cell's fused engine+detector
step under ``dist.ctx`` sharding; finished streams free their lane,
which is zeroed and refilled from the admission queue — the step always
runs at full batch, with no drain barrier.

Execution policy is the same first-class serving flag as offline serve:
``--backend float|lut_float|lut|pallas`` resolves through
``runtime.compile_model`` to an Engine (eq-9 PTQ weights + LUT / Pallas
softmax-GELU for the non-float backends); streaming logits stay
bit-identical to that engine's offline forward either way
(tests/test_stream.py, tests/test_runtime.py).

Overload behaviour (``repro.cell.admission``): offered streams beyond
``--max-queue`` (or past ``--deadline-ms`` of queue wait) are shed
BEFORE any audio is ingested; with ``--degrade-queue`` set, a backed-up
cell first degrades to ``--degrade-chunk-hops`` hops per engine step —
trading detection latency for throughput — and only then rejects.
``--watch-dir`` points the cell at a checkpoint directory for in-flight
hot-swap of freshly published artifacts.

Usage (CPU, reduced):
  PYTHONPATH=src python -m repro.launch.stream_serve --streams 8 --slots 4 \
      --hops 120 [--backend lut] [--train-steps 80]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import cell as cellmod
from repro import runtime
from repro import telemetry
from repro.configs import registry
from repro.data import pipeline
from repro.launch import serve_common
from repro.models import kwt
from repro.stream import detector as det
from repro.stream import engine
from repro.stream import features


def train_params(cfg, fcfg, n_steps: int, seed: int):
    """Quick end-to-end training from raw audio (waveform -> MFCC -> KWT)
    through the canonical ``steps.make_train_step``, so served detections
    are meaningful; n_steps=0 returns random init."""
    params = kwt.init_params(cfg, jax.random.PRNGKey(seed))
    if n_steps <= 0:
        return params
    from repro.configs.base import ShapeSpec
    from repro.launch import steps
    from repro.optim import adamw
    hp = adamw.HParams(lr=3e-3, warmup_steps=max(2, n_steps // 10),
                       total_steps=n_steps, weight_decay=0.0)
    opt = adamw.init(params, hp)
    n = engine.window_frames(cfg) * fcfg.hop_len
    shape = ShapeSpec("stream_train", engine.window_frames(cfg), 64, "train")
    step = jax.jit(steps.make_train_step(cfg, shape, hp, n_micro=1))
    featurize = jax.jit(lambda a: features.mfcc(a, fcfg))

    log_every = max(1, n_steps // 8)
    for i in range(n_steps):
        raw = pipeline.keyword_audio_batch(seed, i, batch=64, n_samples=n)
        params, opt, m = step(params, opt, {"mfcc": featurize(raw["audio"]),
                                            "labels": raw["labels"]})
        if (i + 1) % log_every == 0 or i + 1 == n_steps:
            telemetry.log("train_step", step=i + 1, of=n_steps,
                          loss=float(m["loss"]), lr=float(m["lr"]),
                          grad_norm=float(m["grad_norm"]))
    telemetry.log("train_done", steps=n_steps, loss=float(m["loss"]),
                  source="audio-derived MFCC")
    return params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kwt-tiny")
    ap.add_argument("--streams", type=int, default=8,
                    help="total streams to serve")
    ap.add_argument("--slots", type=int, default=4, help="batch lanes")
    ap.add_argument("--hops", type=int, default=120,
                    help="mean stream length in hops")
    ap.add_argument("--chunk-hops", type=int, default=1,
                    help="hops ingested per engine step")
    ap.add_argument("--backend", default="float",
                    choices=runtime.available_backends(),
                    help="execution backend (runtime.compile_model); "
                         "the former --quantize flag is --backend lut_float")
    ap.add_argument("--train-steps", type=int, default=80,
                    help="0 = serve a randomly initialised model")
    ap.add_argument("--seed", type=int, default=0)
    # admission control (repro.cell.admission); defaults admit everything
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded wait queue (default: --streams)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="shed streams that waited longer than this")
    ap.add_argument("--degrade-queue", type=int, default=0,
                    help=">0: degrade to --degrade-chunk-hops when the "
                         "queue is deeper than this")
    ap.add_argument("--degrade-chunk-hops", type=int, default=4)
    ap.add_argument("--watch-dir", default=None,
                    help="hot-swap checkpoints published here "
                         "(repro.cell.hotswap)")
    serve_common.add_telemetry_args(ap)
    args = ap.parse_args(argv)
    backend = args.backend

    entry = registry.get(args.arch)
    base_cfg = entry.smoke
    assert base_cfg.family == "kwt", "streaming serve drives the KWT family"
    fcfg = features.FrontendConfig()
    dcfg = det.DetectorConfig()

    # training always runs the float path; the engine then owns PTQ + mode
    # selection for serving.
    fparams = train_params(base_cfg, fcfg, args.train_steps, args.seed)
    eng = runtime.compile_model(base_cfg, fparams, backend=backend)
    telemetry.log("engine", plan=eng.describe())

    B, k = args.slots, args.chunk_hops
    queue = list(range(args.streams))
    rng = np.random.RandomState(args.seed)
    sources = {}
    for sid in queue:
        # whole chunks, at least one (wide --chunk-hops must not floor to 0)
        hops = max(k, int(rng.randint(args.hops // 2, args.hops * 2))
                   // k * k)
        audio, events = pipeline.keyword_event_stream(
            args.seed, sid, n_hops=hops, hop_len=fcfg.hop_len)
        sources[sid] = {"audio": audio, "events": events, "hops": hops}

    adm = cellmod.AdmissionConfig(
        max_queue=args.max_queue if args.max_queue is not None
        else max(args.streams, 1),
        deadline_ms=args.deadline_ms,
        degrade_queue=args.degrade_queue if args.degrade_queue > 0
        else args.streams + 1,
        degraded_chunk_hops=max(args.degrade_chunk_hops, k))

    with serve_common.session(args.telemetry_out) as (tracer, met):
        probe = np.zeros((1,) + tuple(base_cfg.input_dim), np.float32)
        cell = cellmod.ServeCell(
            eng, slots=B, registry=met, admission=adm,
            watch_dir=args.watch_dir,
            watch_like=eng.params if args.watch_dir else None,
            probe=probe if args.watch_dir else None)
        with cell:
            fired = _serve(cell, sources, queue, fcfg, dcfg, k, met)
    return fired


def _serve(cell, sources, queue, fcfg, dcfg, chunk_hops, met):
    """The serve loop proper: offer -> join -> hop -> evict, to drain."""
    lanes = cell.stream_lanes(fcfg, dcfg, chunk_hops=chunk_hops)
    B = cell.slots
    shed = []
    for sid in queue:
        if not cell.admission.offer(sid).admitted:
            shed.append(sid)
    n_to_serve = len(queue) - len(shed)

    events_ctr = met.counter("serve_detector_events_total",
                             "keyword detections fired")
    rtf = met.histogram("serve_stream_rtf", "per-stream real-time "
                        "factor (wall seconds / audio seconds; <1 is "
                        "faster than realtime)", unit="x")

    active = [None] * B          # stream id per lane
    offset = np.zeros(B, np.int64)
    started = np.zeros(B, np.float64)      # lane fill wall time
    fired, done = [], []
    eng = cell.engine
    t0 = time.time()
    while len(done) < n_to_serve:
        cell.maybe_swap()
        with telemetry.span("refill"):
            for lane in lanes.free_lanes():
                sid = cell.admission.pop()
                if sid is None:
                    break
                lanes.join(lane)
                active[lane] = sid
                offset[lane] = 0
                started[lane] = time.time()
        # overload degrade: a backed-up queue widens the chunk cell-wide
        lanes.set_chunk_hops(max(chunk_hops, cell.admission.chunk_hops()))
        cs = lanes.chunk_samples
        chunk = np.zeros((B, cs), np.float32)
        ingest = np.zeros(B, np.int64)
        with telemetry.span("pack"):
            for i in range(B):
                sid = active[i]
                if sid is None:
                    continue
                a = sources[sid]["audio"]
                end = sources[sid]["hops"] * fcfg.hop_len
                n = int(min(cs, end - offset[i]))
                chunk[i, :n] = a[offset[i]:offset[i] + n]
                offset[i] += n
                ingest[i] = n // fcfg.hop_len
        with telemetry.span("hop", {"backend": eng.backend_name}):
            events = lanes.hop(chunk, ingest=ingest)
        with telemetry.span("detector"):
            for i in range(B):
                sid = active[i]
                if sid is None:
                    continue
                if events["fired"][i]:
                    hop = int(offset[i] // fcfg.hop_len)
                    fired.append((sid, hop))
                    events_ctr.inc()
                    telemetry.log(
                        "detector_event", stream=sid,
                        t_s=det.event_time_s(hop, fcfg),
                        score=float(events["score"][i]),
                        backend=eng.backend_name)
                if offset[i] >= sources[sid]["hops"] * fcfg.hop_len:
                    done.append(sid)
                    lanes.evict(i)
                    active[i] = None
                    audio_s_i = sources[sid]["hops"] \
                        * fcfg.hop_len / fcfg.sample_rate
                    rtf.observe((time.time() - started[i]) / audio_s_i)
    dt = time.time() - t0
    served = [s for sid, s in sources.items() if sid in done]
    audio_s = sum(s["hops"] for s in served) * fcfg.hop_len / fcfg.sample_rate
    truth = sum(len(s["events"]) for s in served)
    telemetry.log("serve_done", streams=n_to_serve, shed=len(shed),
                  audio_s=audio_s, wall_s=dt, realtime_x=audio_s / dt,
                  fired=len(fired), keywords=truth,
                  ingested_hops=int(met.counter("cell_hops_total").value),
                  offered_hops=sum(s["hops"] for s in served),
                  backend=eng.backend_name,
                  **met.histogram("cell_hop_latency_ms").summary())
    return fired


if __name__ == "__main__":
    main()
