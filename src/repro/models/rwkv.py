"""RWKV-6 "Finch" (attention-free, data-dependent decay) — rwkv6-3b.

Time-mix recurrence per head (head_dim=64):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (w_t in (0,1), per channel)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t data-dependent (LoRA on the decay, the Finch hallmark).

Computed in chunks of ``CHUNK`` tokens: within a chunk the pairwise decay
factor exp(cum_t - cum_j) is materialised as an exact log-space difference
tensor [B,H,c,c,Dh] (c=16 keeps it ~3 MB/device) — numerically exact, no
decay clamping; across chunks a ``lax.scan`` carries S.  ``chunk_body`` is
exported while-free so the dry-run can cost it precisely (cost_analysis
counts while bodies once; see DESIGN.md §4).

The paper's technique hooks: RWKV has **no softmax** in time-mix
(LUT-softmax inapplicable — DESIGN.md §Arch-applicability); channel-mix's
ReLU^2 is polynomial; the receptance sigmoid uses the bounded-domain LUT
when cfg.act_approx != "exact"; int8 PTQ applies to all projections.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import approx
from repro.models import layers as L

CHUNK = 16
HEAD_DIM = 64
LORA_DIM = 64


def n_heads(cfg) -> int:
    """Head count, padded to a TP multiple when cfg.rwkv_head_pad (§Perf H2:
    40 heads cannot shard over model=16 -> r/k/v/lw tensors replicate and
    all-gather; zero-initialised pad heads are function-preserving)."""
    h = cfg.d_model // HEAD_DIM
    if cfg.rwkv_head_pad:
        h = -(-h // 16) * 16
    return h


def _pad_cols(w, inner, d_out):
    """Zero-pad a [*, inner_real] projection to [*, d_out] (pad heads)."""
    if w.shape[-1] == d_out:
        return w
    pad = jnp.zeros(w.shape[:-1] + (d_out - w.shape[-1],), w.dtype)
    return jnp.concatenate([w, pad], axis=-1)


def _sigmoid(x, cfg):
    return (approx.sigmoid_lut(x) if cfg.act_approx != "exact"
            else jax.nn.sigmoid(x.astype(jnp.float32)))


def time_mix_params(cfg, key):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    h = n_heads(cfg)
    di = h * HEAD_DIM                 # inner width (padded when head_pad)
    return {
        # static token-shift interpolation vectors (mu_r/k/v/w/g)
        "mu": jnp.full((5, d), 0.5, jnp.float32),
        **({"wrkvg": jnp.concatenate(
                [_pad_cols(L.he(ks[i], (d, d), 1.0, dt), d, di)
                 for i in range(4)], axis=1)}   # [d, 4*di] fused projection
           if cfg.rwkv_fused_proj else
           {"wr": _pad_cols(L.he(ks[0], (d, d), 1.0, dt), d, di),
            "wk": _pad_cols(L.he(ks[1], (d, d), 1.0, dt), d, di),
            "wv": _pad_cols(L.he(ks[2], (d, d), 1.0, dt), d, di),
            "wg": _pad_cols(L.he(ks[3], (d, d), 1.0, dt), d, di)}),
        "wo": jnp.concatenate([
            L.he(ks[4], (d, d), 1.0, dt),
            jnp.zeros((di - d, d), dt)], axis=0) if di != d
        else L.he(ks[4], (d, d), 1.0, dt),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((di,), -5.0, jnp.float32),
        "wA": L.he(ks[5], (d, LORA_DIM), 1.0, jnp.float32),
        "wB": _pad_cols(L.he(ks[6], (LORA_DIM, d), 0.1, jnp.float32), d, di),
        "u": jnp.zeros((h, HEAD_DIM), jnp.float32),   # bonus
        "ln_x": jnp.ones((di,), jnp.float32),         # per-head group norm
    }


def time_mix_specs(cfg):
    tp = L.TP if cfg.rwkv_head_pad else L.TP   # proj out dims always TP-able
    hspec = L.TP if cfg.rwkv_head_pad else None  # padded heads shard over TP
    proj = ({"wrkvg": P(L.FSDP, tp)} if cfg.rwkv_fused_proj else
            {"wr": P(L.FSDP, tp), "wk": P(L.FSDP, tp),
             "wv": P(L.FSDP, tp), "wg": P(L.FSDP, tp)})
    return {"mu": P(None, None), **proj,
            "wo": P(tp, L.FSDP),
            "w0": P(tp), "wA": P(None, None), "wB": P(None, tp),
            "u": P(hspec, None), "ln_x": P(tp)}


def channel_mix_params(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {"mu": jnp.full((2, d), 0.5, jnp.float32),
            "wk": L.he(ks[0], (d, f), 1.0, dt),
            "wv": L.he(ks[1], (f, d), 1.0, dt),
            "wr": L.he(ks[2], (d, d), 1.0, dt)}


def channel_mix_specs(cfg):
    f, t = L.fsdp_axis(cfg), L.tp_axis(cfg)
    return {"mu": P(None, None), "wk": P(f, t),
            "wv": P(t, f), "wr": P(f, t)}


def _token_shift(x, x_prev):
    """x [B,S,D]; x_prev [B,1,D] (last token of previous segment)."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu


def chunk_body(S, chunk, u):
    """One chunk of the wkv recurrence.  While-free; exported for costing.

    S [B,H,Dk,Dv]; chunk = dict(r,k,v [B,H,c,Dh], lw [B,H,c,Dh] = log w).
    Returns (S_new, y [B,H,c,Dh]).
    """
    r, k, v, lw = chunk["r"], chunk["k"], chunk["v"], chunk["lw"]
    cum = jnp.cumsum(lw, axis=2)                      # inclusive  [B,H,c,D]
    cumx = cum - lw                                   # exclusive
    # inter-chunk: y_t += (r_t . e^{cumx_t}) @ S
    y = jnp.einsum("bhtd,bhde->bhte", r * jnp.exp(cumx), S)
    # intra-chunk: exact log-space pairwise decay, strictly lower-triangular
    diff = cumx[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,H,c,c,D]
    c = r.shape[2]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, None, :, :, None]
    amat = jnp.sum(jnp.where(tri, jnp.exp(diff), 0.0)
                   * r[:, :, :, None, :] * k[:, :, None, :, :], axis=-1)
    # diagonal bonus term: A[t,t] = sum_d r u k
    adiag = jnp.einsum("bhtd,hd,bhtd->bht", r, u, k)
    amat = amat + jnp.eye(c)[None, None] * adiag[:, :, :, None]
    y = y + jnp.einsum("bhtj,bhje->bhte", amat, v)
    # state update: S' = e^{cum_c} . S + sum_j (k_j e^{cum_c - cum_j}) v_j
    total = cum[:, :, -1:, :]                          # [B,H,1,D]
    S_new = (jnp.exp(total[:, :, 0, :, None]) * S
             + jnp.einsum("bhjd,bhje->bhde", k * jnp.exp(total - cum), v))
    return S_new, y


def wkv_scan(r, k, v, lw, u, S0):
    """Chunked scan over time.  r/k/v/lw [B,H,S,Dh] -> y, S_final.

    Handles arbitrary S: full chunks go through ``lax.scan``; the
    remainder (and S < CHUNK, e.g. decode) is one direct chunk_body call.
    """
    b, h, s, dh = r.shape
    main = (s // CHUNK) * CHUNK
    S = S0
    parts = []
    if main:
        nc = main // CHUNK
        xs = jax.tree.map(
            lambda a: a[:, :, :main].reshape(b, h, nc, CHUNK, dh)
            .transpose(2, 0, 1, 3, 4),
            {"r": r, "k": k, "v": v, "lw": lw})

        def body(S, chunk):
            S, y = chunk_body(S, chunk, u)
            return S, y

        S, ys = jax.lax.scan(body, S, xs)             # ys [nc,B,H,c,Dh]
        parts.append(ys.transpose(1, 2, 0, 3, 4).reshape(b, h, main, dh))
    if s > main:
        tail = {kk: a[:, :, main:] for kk, a in
                {"r": r, "k": k, "v": v, "lw": lw}.items()}
        S, y = chunk_body(S, tail, u)
        parts.append(y)
    y = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=2)
    return y, S


def wkv_naive(r, k, v, lw, u, S0):
    """Step-by-step oracle for tests: same math, one token at a time."""
    def step(S, inp):
        rt, kt, vt, lwt = inp
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,Dk,Dv]
        y = jnp.einsum("bhd,bhde->bhe", rt, S + u[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, y

    xs = jax.tree.map(lambda a: a.transpose(2, 0, 1, 3), (r, k, v, lw))
    S, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 2, 0, 3), S


def apply_time_mix(p, x, cfg, state):
    """state = dict(S [B,H,Dk,Dv], x_prev [B,1,D]); returns (out, state)."""
    b, s, d = x.shape
    h = n_heads(cfg)
    xx = _token_shift(x, state["x_prev"])
    xf, xxf = x.astype(jnp.float32), xx.astype(jnp.float32)
    mr, mk, mv, mw, mg = [p["mu"][i] for i in range(5)]
    dt = x.dtype
    if "wrkvg" in p:
        # fused projection: the 4 per-tensor token-shift mixes are stacked
        # on a new leading axis and contracted in ONE matmul -> one TP
        # psum instead of four (§Perf H2 it3)
        mixed = jnp.stack([_mix(xf, xxf, m).astype(dt)
                           for m in (mr, mk, mv, mg)], axis=0)  # [4,B,S,D]
        di = p["wrkvg"].shape[1] // 4
        w4 = p["wrkvg"].reshape(p["wrkvg"].shape[0], 4, di)
        out4 = jnp.einsum("nbsd,dnf->nbsf", mixed, w4)
        r, k, v, g = out4[0], out4[1], out4[2], out4[3]
    else:
        r = jnp.einsum("bsd,df->bsf", _mix(xf, xxf, mr).astype(dt), p["wr"])
        k = jnp.einsum("bsd,df->bsf", _mix(xf, xxf, mk).astype(dt), p["wk"])
        v = jnp.einsum("bsd,df->bsf", _mix(xf, xxf, mv).astype(dt), p["wv"])
        g = jnp.einsum("bsd,df->bsf", _mix(xf, xxf, mg).astype(dt), p["wg"])
    xw = _mix(xf, xxf, mw)
    lw_raw = p["w0"] + jnp.tanh(xw @ p["wA"]) @ p["wB"]
    lw = -jnp.exp(lw_raw.astype(jnp.float32))          # log w_t  (< 0)

    di = h * HEAD_DIM

    def heads(a):
        return a.reshape(b, s, h, HEAD_DIM).transpose(0, 2, 1, 3).astype(jnp.float32)

    y, S = wkv_scan(heads(r), heads(k), heads(v), heads(lw), p["u"], state["S"])
    y = y.transpose(0, 2, 1, 3)
    # per-head group norm + gate
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6)
    y = (y.reshape(b, s, di) * p["ln_x"]).astype(dt)
    y = y * _sigmoid(g, cfg).astype(dt)
    out = jnp.einsum("bsd,df->bsf", y, p["wo"])
    return out, {"S": S, "x_prev": x[:, -1:, :]}


def apply_channel_mix(p, x, cfg, state):
    xx = _token_shift(x, state["x_prev"])
    xf, xxf = x.astype(jnp.float32), xx.astype(jnp.float32)
    mk, mr = p["mu"][0], p["mu"][1]
    dt = x.dtype
    k = jnp.einsum("bsd,df->bsf", _mix(xf, xxf, mk).astype(dt), p["wk"])
    k = jnp.square(jnp.maximum(k.astype(jnp.float32), 0.0)).astype(dt)  # ReLU^2
    v = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    rr = jnp.einsum("bsd,df->bsf", _mix(xf, xxf, mr).astype(dt), p["wr"])
    out = _sigmoid(rr, cfg).astype(dt) * v
    return out, {"x_prev": x[:, -1:, :]}


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def block_params(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_params(cfg), "ln2": L.norm_params(cfg),
            "tmix": time_mix_params(cfg, k1),
            "cmix": channel_mix_params(cfg, k2)}


def block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg),
            "tmix": time_mix_specs(cfg), "cmix": channel_mix_specs(cfg)}


def apply_block(bp, x, cfg, state):
    h, s1 = apply_time_mix(bp["tmix"], L.apply_norm(bp["ln1"], x, cfg), cfg,
                           state["tmix"])
    x = x + h
    h, s2 = apply_channel_mix(bp["cmix"], L.apply_norm(bp["ln2"], x, cfg), cfg,
                              state["cmix"])
    return x + h, {"tmix": s1, "cmix": s2}


def init_layer_state(cfg, batch):
    d = cfg.d_model
    h = n_heads(cfg)
    return {
        "tmix": {"S": jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
                 "x_prev": jnp.zeros((batch, 1, d), jnp.dtype(cfg.dtype))},
        "cmix": {"x_prev": jnp.zeros((batch, 1, d), jnp.dtype(cfg.dtype))},
    }


def state_specs(cfg, dp=("data",)):
    hspec = L.TP if cfg.rwkv_head_pad else None
    return {
        "tmix": {"S": P(dp, hspec, None, None), "x_prev": P(dp, None, None)},
        "cmix": {"x_prev": P(dp, None, None)},
    }
