"""The Keyword Transformer (paper §II-III): KWT-1 and KWT-Tiny.

ViT-style *post-norm* encoder over MFCC spectrogram patches (Fig 1):
  X [B, F, T] -> per-time-step patches [B, T, F] -> linear proj to d
  -> prepend class token -> + learned positional embeddings
  -> DEPTH transformer blocks (eq 1-6) -> class-token head (eq 8).

KWT-Tiny: INPUT_DIM [16,26], PATCH [16,1], DIM 12, DEPTH 1, HEADS 1,
MLP_DIM 24, DIM_HEAD 8, SEQLEN 27, 2 classes (Table III).  The attention
inner dim (HEADS*DIM_HEAD = 8) differs from DIM=12 — handled by
cfg.head_dim.  LayerNorm + GELU + biases everywhere, exactly the paper's
C library op set (Table VI).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.telemetry import taps as _health


def seqlen(cfg) -> int:
    return cfg.input_dim[1] + 1          # T time patches + class token


def init_params(cfg, key):
    f, t = cfg.input_dim
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3 + cfg.n_layers)
    p = {
        "proj_w": L.he(ks[0], (f, d), 1.0, dt),
        "proj_b": jnp.zeros((d,), dt),
        "cls": jnp.zeros((d,), dt),
        "pos": L.he(ks[1], (t + 1, d), 0.02, dt),
        "blocks": [  # depth <= 12: explicit list, no scan needed
            {"ln1": L.norm_params(cfg), "ln2": L.norm_params(cfg),
             "attn": L.attention_params(cfg, ks[3 + i]),
             "mlp": L.mlp_params(cfg, jax.random.fold_in(ks[3 + i], 7))}
            for i in range(cfg.n_layers)],
        "head_w": L.he(ks[2], (d, cfg.n_classes), 1.0, dt),
        "head_b": jnp.zeros((cfg.n_classes,), dt),
    }
    return p


def param_specs(cfg):
    return {
        "proj_w": P(None, None), "proj_b": P(None), "cls": P(None),
        "pos": P(None, None),
        "blocks": [{"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg),
                    "attn": L.attention_specs(cfg),
                    "mlp": L.mlp_specs(cfg)} for _ in range(cfg.n_layers)],
        "head_w": P(None, None), "head_b": P(None),
    }


def embed_frames(params, frames, cfg):
    """Patch-embed time-major frames [B, t, F] -> [B, t, d] (paper Fig 1,
    per-time-step [16, 1] patches).

    Factored out of :func:`forward` so the streaming engine
    (``repro.stream.engine``) can embed only newly arrived frames per hop
    and cache the rest — the einsum contracts over F per frame, so the
    result for a frame is independent of which other frames share the
    batch, keeping the streaming path bit-identical to offline.
    """
    x = frames.astype(jnp.dtype(cfg.dtype))
    return L.linear(x, params["proj_w"], "btf,fd->btd", cfg) + params["proj_b"]


def encode_window(params, x, cfg):
    """Embedded window [B, T, d] -> logits [B, n_classes]: class token +
    positions + post-norm blocks + head (paper §II eqs 1-6, 8)."""
    b = x.shape[0]
    cls = jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model))
    # pos is a rank-2 leaf, so quantising recipes store it as a QTensor;
    # it is consumed additively, so integer-resident trees dequantise it
    # in-jit (same po2 de-scale the plan-time dequant would have applied).
    x = jnp.concatenate([cls, x], axis=1) + L.asfloat(params["pos"])
    _health.tap_activation("embed", x, cfg)
    for i, bp in enumerate(params["blocks"]):
        # post-norm residual blocks (paper §II eqs 1-6), full attention;
        # taps.scope names this block's health stats (block0/softmax ...)
        with _health.scope(f"block{i}"):
            a, _ = L.apply_attention(bp["attn"], x, cfg,
                                     positions=jnp.arange(x.shape[1]),
                                     causal=False)
            x = L.apply_norm(bp["ln1"], x + a, cfg)
            f = L.apply_mlp(bp["mlp"], x, cfg)
            x = L.apply_norm(bp["ln2"], x + f, cfg)
            _health.tap_activation("block_out", x, cfg)
    return (L.linear(x[:, 0], params["head_w"], "bd,dc->bc", cfg)
            + params["head_b"]).astype(jnp.float32)


def forward(params, mfcc, cfg):
    """mfcc [B, F, T] -> logits [B, n_classes]."""
    x = embed_frames(params, jnp.swapaxes(mfcc, 1, 2), cfg)     # [B,T,d]
    return encode_window(params, x, cfg)


def loss_fn(params, batch, cfg):
    logits = forward(params, batch["mfcc"], cfg)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params, batch, cfg):
    logits = forward(params, batch["mfcc"], cfg)
    return jnp.mean(jnp.argmax(logits, -1) == batch["labels"])


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
