"""Shared transformer layers: norms, RoPE, GQA attention, (gated) MLP.

Functional style: ``*_params(cfg, key)`` builds a pytree of weights,
``*_specs(cfg)`` builds the *same-structured* tree of PartitionSpecs
(FSDP over 'data' x TP over 'model'; DESIGN.md §3), ``apply_*`` runs the
math.  The paper's technique enters through ``cfg.softmax_mode`` /
``cfg.act_approx`` (LUT approximations) and ``cfg.quant`` (int8 weights).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import approx
from repro.core import quant
from repro.telemetry import taps as _health

# Mesh axis conventions (see launch/mesh.py):
FSDP = "data"     # parameter shard axis (ZeRO-3 style)
TP = "model"      # tensor-parallel axis


def linear(x, w, eq: str, cfg=None):
    """One linear layer, weight either float or a stored-integer QTensor.

    Integer-EXECUTING plans (``cfg.int_exec``, pinned by
    ``runtime.compile_model`` on the lut/pallas backends) quantise the
    input with the eq-9 activation quantiser and multiply the stored
    int8 / nibble-packed int4 payload directly, with a per-channel po2
    requant epilogue (``quant.int_exec_einsum``) — no float weight view.
    Unsupported layouts (per-channel exponents on the contraction axis,
    i.e. the tied-embedding head) and non-executing resident plans keep
    the PR-5 path: ``quant.qt_einsum`` materialises the exact float view
    per call, bit-identical to dequantise-first.
    """
    if isinstance(w, quant.QTensor):
        if cfg is not None and cfg.int_exec and \
                quant.int_exec_supported(w, eq):
            q = cfg.quant
            return quant.int_exec_einsum(
                eq, x, w,
                x_exp=q.input_exponent if q is not None else 5,
                residual_bits=q.residual_bits if q is not None else 16,
                use_kernel=(cfg.act_approx == "pallas"
                            and not cfg.kernel_interpret),
                interpret=cfg.kernel_interpret)
        return quant.qt_einsum(eq, x, w)
    return jnp.einsum(eq, x, w)


def embed_rows(embed, tokens, gather=None):
    """Embedding lookup, table either float or a stored-integer QTensor.

    QTensor tables gather integer rows and descale only what was looked
    up (``quant.gather_descale``) — the LM embed family's integer-
    residency path; the full table never materialises as float.
    ``gather`` overrides the float-path lookup (e.g. the dist-sharded
    ``ctx.embed_lookup``)."""
    if isinstance(embed, quant.QTensor):
        return quant.gather_descale(embed, tokens)
    if gather is not None:
        return gather(embed, tokens)
    return jnp.take(embed, tokens, axis=0)


def asfloat(w):
    """Dequantise a QTensor consumed outside a matmul (e.g. additive
    positional embeddings); floats pass through untouched."""
    return quant.resident_values(w) if isinstance(w, quant.QTensor) else w


def fsdp_axis(cfg):
    """Weight shard axis/axes.  pure_fsdp: ZeRO-3 over the whole mesh
    (no TP) — optimal for small archs where TP activation psums dominate
    (hillclimb H1).  tp_only: TP-resident weights, no FSDP gathers —
    optimal for decode, where per-layer weight all-gathers dominate the
    collective term (hillclimb H3)."""
    if cfg.pure_fsdp:
        return ("data", "model")
    if cfg.tp_only:
        return None
    return FSDP


def tp_axis(cfg):
    return None if cfg.pure_fsdp else TP


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def he(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    return (jax.random.normal(key, shape) * (scale / np.sqrt(fan_in))).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_params(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_specs(cfg):
    if cfg.norm == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {"scale": P(None)}


def apply_norm(p, x, cfg, eps=1e-6):
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        # paper eqs (4)-(5): mean/variance normalise, then gamma/beta.
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"] + p["bias"]).astype(_dtype(cfg))
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps) * p["scale"]).astype(_dtype(cfg))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions [S] (or [B,S]) -> cos/sin tables [..., S, head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x [..., S, H, D]; cos/sin broadcastable [..., S, 1, D/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / qkv-bias / sliding window / KV cache)
# ---------------------------------------------------------------------------

def attention_params(cfg, key):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": he(ks[0], (d, h * dh), 1.0, dt),
        "wk": he(ks[1], (d, kv * dh), 1.0, dt),
        "wv": he(ks[2], (d, kv * dh), 1.0, dt),
        "wo": he(ks[3], (h * dh, d), 1.0, dt),
    }
    if cfg.qkv_bias or cfg.bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((kv * dh,), dt)
        p["bv"] = jnp.zeros((kv * dh,), dt)
    if cfg.bias:
        p["bo"] = jnp.zeros((d,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def attention_specs(cfg):
    f, t = fsdp_axis(cfg), tp_axis(cfg)
    s = {"wq": P(f, t), "wk": P(f, t), "wv": P(f, t),
         "wo": P(t, f)}
    if cfg.qkv_bias or cfg.bias:
        s.update({"bq": P(t), "bk": P(t), "bv": P(t)})
    if cfg.bias:
        s["bo"] = P(None)
    if cfg.qk_norm:
        s.update({"q_norm": P(None), "k_norm": P(None)})
    return s


def _rms(x, scale, eps=1e-6):
    x = x.astype(jnp.float32)
    return (x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
            * scale)


Q_CHUNK = 512       # query-chunked XLA attention: bounds the score matrix
                    # (512: worst-case f32 tile at 32k keys stays ~2.7 GB)


def _sdpa_block(q, k, v, cfg, *, q0, k0, q_offset, kv_len_valid, causal):
    """One [qc, kc] tile of masked attention.  q [B,qc,H,D]; k/v [B,kc,KV,D].

    q0/k0: static tile offsets within the (chunked) sequence;
    q_offset: (possibly traced) absolute position of sequence start —
    scalar, or a per-lane [B] vector when lanes decode at heterogeneous
    depths (the repro.cell continuous-batching path; ``kv_len_valid``
    then carries the matching per-lane validity bound).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    # operands stay in model dtype; f32 ACCUMULATION via
    # preferred_element_type (MXU-native).  An explicit .astype(f32) on
    # k/v makes XLA hoist a full-precision copy of the whole stacked KV
    # cache out of the layer scan (measured +3.8 GB/device on deepseek).
    qf = q.reshape(b, sq, kv, g, dh)
    acc_dt = jnp.dtype(cfg.scores_dtype)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                   preferred_element_type=acc_dt)
    s = s * jnp.asarray(dh ** -0.5, acc_dt)
    q_off = jnp.asarray(q_offset)
    if q_off.ndim:                                       # per-lane [B]
        q_off = q_off[:, None]
    qpos = q_off + q0 + jnp.arange(sq)                   # [sq] or [B, sq]
    kpos = k0 + jnp.arange(sk)                           # [sk]
    # mask stays None when nothing masks (full bidirectional attention,
    # e.g. KWT): the softmax paths then skip the select ops entirely and
    # the pallas mode is the raw kernel output, bit-identical to
    # kernels.ops.lut_softmax.
    mask = None
    if causal:
        mask = qpos[..., :, None] >= kpos
    if cfg.sliding_window and causal:
        # ring-buffer (causal=False) paths enforce the window by overwrite;
        # position-based banding only applies to contiguous layouts.
        mask = jnp.logical_and(
            mask, kpos > qpos[..., :, None] - cfg.sliding_window)
    if kv_len_valid is not None:
        kvv = jnp.asarray(kv_len_valid)
        if kvv.ndim:                                     # per-lane [B]
            valid = kpos < kvv[:, None, None]            # [B, 1, sk]
        else:
            valid = jnp.broadcast_to((kpos < kvv)[None, :], (sq, sk))
        mask = valid if mask is None else jnp.logical_and(mask, valid)
    if mask is not None:
        if mask.ndim == 2:                          # [sq, sk]: shared lanes
            mask = mask[None, None, None]           # broadcast over b, kv, g
        else:                                       # [B, ., sk]: per-lane
            mask = jnp.broadcast_to(mask, (b, sq, sk))[:, None, None]
    p = approx.masked_softmax(s, mask, mode=cfg.softmax_mode,
                              interpret=cfg.kernel_interpret)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def sdpa(q, k, v, cfg, *, q_offset, kv_len_valid, causal=True):
    """Masked GQA attention, XLA path, query-chunked.

    Long sequences are processed in static query chunks so the live score
    tile is [qc, k_window] instead of [Sq, Sk]; with a sliding window the
    key range of each chunk is statically sliced -> banded compute (the
    sub-quadratic path hymba's long shapes rely on).  Chunking applies only
    when q_offset is the static 0 (prefill/train); decode (Sq small) takes
    the single-tile path.
    """
    sq, sk = q.shape[1], k.shape[1]
    if sq <= Q_CHUNK:
        return _sdpa_block(q, k, v, cfg, q0=0, k0=0, q_offset=q_offset,
                           kv_len_valid=kv_len_valid, causal=causal)
    assert isinstance(q_offset, int) and q_offset == 0, \
        "chunked attention assumes prefill/train (static positions)"
    outs = []
    for q0 in range(0, sq, Q_CHUNK):
        qc = q[:, q0:q0 + Q_CHUNK]
        # static key window for this chunk (absolute positions are
        # left-aligned: qpos == kpos at the same index)
        khi = min(sk, q0 + qc.shape[1]) if causal else sk
        klo = max(0, q0 - cfg.sliding_window + 1) if cfg.sliding_window else 0
        outs.append(_sdpa_block(
            qc, k[:, klo:khi], v[:, klo:khi], cfg, q0=q0, k0=klo,
            q_offset=0, kv_len_valid=kv_len_valid, causal=causal))
    return jnp.concatenate(outs, axis=1)


def apply_attention(p, x, cfg, *, positions, cache=None, cache_index=None,
                    kv_len_valid=None, causal=True):
    """Returns (out, new_cache).  cache = dict(k=[B,S,KV,D], v=...) or None.

    Ring-buffer caches (hybrid sliding window) pass causal=False plus an
    explicit ``kv_len_valid``: every live slot is a valid past key and the
    window property is enforced by overwrite.
    """
    b, sq, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    _health.tap_activation("attn_in", x, cfg)
    wq, wk, wv = p["wq"], p["wk"], p["wv"]
    if (cfg is not None and cfg.int_exec
            and not (cfg.act_approx == "pallas" and not cfg.kernel_interpret)
            and all(isinstance(w, quant.QTensor)
                    and quant.int_exec_supported(w, "bsd,df->bsf")
                    for w in (wq, wk, wv))):
        # one fused int8 x int8 projection dot instead of three —
        # bitwise equal to the separate calls (see quant.int_exec_qkv)
        qm = cfg.quant
        q, k, v = quant.int_exec_qkv(
            x, (wq, wk, wv),
            x_exp=qm.input_exponent if qm is not None else 5,
            residual_bits=qm.residual_bits if qm is not None else 16)
    else:
        q = linear(x, wq, "bsd,df->bsf", cfg)
        k = linear(x, wk, "bsd,df->bsf", cfg)
        v = linear(x, wv, "bsd,df->bsf", cfg)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, h, dh)
    k = k.reshape(b, sq, kv, dh)
    v = v.reshape(b, sq, kv, dh)
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"]).astype(x.dtype)
        k = _rms(k, p["k_norm"]).astype(x.dtype)
    if cfg.use_rope:
        cos, sin = rope_tables(positions, dh, cfg.rope_theta)
        cos, sin = cos[..., :, None, :], sin[..., :, None, :]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if cache is None:
        if _use_flash_lut(cfg, kv_len_valid):
            # flash-LUT kernel path (kernels.lut_attention): online-softmax
            # tiling with the paper's LUT exp, routed here by the runtime
            # Backend / compile_model(attention="flash_lut").  Cacheless
            # full/causal attention only; ring-buffer and windowed layouts
            # keep the XLA sdpa path.
            from repro.kernels import ops
            out = ops.lut_attention(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                jnp.swapaxes(v, 1, 2), causal=causal,
                interpret=cfg.kernel_interpret)
            out = jnp.swapaxes(out, 1, 2)
        else:
            out = sdpa(q, k, v, cfg, q_offset=0, kv_len_valid=kv_len_valid,
                       causal=causal)
        new_cache = None
    elif _kv_quantized(cfg):
        idx = cache_index
        kq, kscale = _q8_vec(k)
        vq, vscale = _q8_vec(v)
        if getattr(idx, "ndim", 0) == 1:     # per-lane decode (repro.cell)
            assert sq == 1, "per-lane cache_index is a decode-only path"
            lanes = jnp.arange(b)
            ck = cache["k"].at[lanes, idx].set(kq[:, 0])
            cv = cache["v"].at[lanes, idx].set(vq[:, 0])
            cks = cache["ks"].at[lanes, idx].set(kscale[:, 0])
            cvs = cache["vs"].at[lanes, idx].set(vscale[:, 0])
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, idx, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["ks"], kscale,
                                               (0, idx, 0))
            cvs = jax.lax.dynamic_update_slice(cache["vs"], vscale,
                                               (0, idx, 0))
        valid = (idx + sq) if kv_len_valid is None else kv_len_valid
        q_off = idx if sq <= Q_CHUNK else 0
        out = sdpa(q, _q8_vec_decode(ck, cks, x.dtype),
                   _q8_vec_decode(cv, cvs, x.dtype), cfg, q_offset=q_off,
                   kv_len_valid=valid, causal=causal)
        new_cache = {"k": ck, "ks": cks, "v": cv, "vs": cvs}
        out = linear(out.reshape(b, sq, h * dh), p["wo"], "bsf,fd->bsd", cfg)
        if "bo" in p:
            out = out + p["bo"]
        return out.astype(x.dtype), new_cache
    else:
        idx = cache_index
        if getattr(idx, "ndim", 0) == 1:     # per-lane decode (repro.cell)
            assert sq == 1, "per-lane cache_index is a decode-only path"
            lanes = jnp.arange(b)
            ck = cache["k"].at[lanes, idx].set(k[:, 0])
            cv = cache["v"].at[lanes, idx].set(v[:, 0])
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        # barrier: stops XLA (notably the CPU bf16-dot lowering) from
        # hoisting f32 converts through the DUS into the scan's ys buffer,
        # which would keep a full-precision copy of the stacked KV cache.
        ck_use, cv_use = jax.lax.optimization_barrier((ck, cv))
        valid = (idx + sq) if kv_len_valid is None else kv_len_valid
        # Multi-token cache writes beyond Q_CHUNK are prefills of a *fresh*
        # cache (index 0): a static offset enables chunked/banded attention.
        # (Serve drivers chunk incremental prefills to <= Q_CHUNK tokens.)
        q_off = idx if sq <= Q_CHUNK else 0
        out = sdpa(q, ck_use, cv_use, cfg, q_offset=q_off,
                   kv_len_valid=valid, causal=causal)
        new_cache = {"k": ck, "v": cv}
    out = linear(out.reshape(b, sq, h * dh), p["wo"], "bsf,fd->bsd", cfg)
    if "bo" in p:
        out = out + p["bo"]
    return out.astype(x.dtype), new_cache


def _kv_quantized(cfg) -> bool:
    return bool(cfg.quant and cfg.quant.quantize_kv_cache)


def _use_flash_lut(cfg, kv_len_valid) -> bool:
    """The flash-LUT kernel serves the cacheless full/causal layouts; a
    sliding window or explicit validity mask needs sdpa's banding."""
    return (cfg.attn_impl == "flash_lut" and kv_len_valid is None
            and not cfg.sliding_window)


def init_kv_cache(cfg, batch, max_len, dtype=None):
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if _kv_quantized(cfg):
        # paper eq 9 applied to the KV cache: int8 values + per-vector
        # power-of-2 scale exponents (stored as f32 scales)
        return {"k": jnp.zeros((batch, max_len, kv, dh), jnp.int8),
                "ks": jnp.ones((batch, max_len, kv), jnp.float32),
                "v": jnp.zeros((batch, max_len, kv, dh), jnp.int8),
                "vs": jnp.ones((batch, max_len, kv), jnp.float32)}
    dt = dtype or _dtype(cfg)
    return {"k": jnp.zeros((batch, max_len, kv, dh), dt),
            "v": jnp.zeros((batch, max_len, kv, dh), dt)}


def _q8_vec(x):
    """Per-(token, kv-head) power-of-2 int8 quantisation of [B,S,KV,D]."""
    maxabs = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    e = jnp.ceil(jnp.log2(jnp.maximum(maxabs, 1e-30) / 127.0))
    scale = jnp.exp2(e)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _q8_vec_decode(q, scale, dt):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dt)


def kv_cache_specs(cfg, dp=("data",), tp_size=16):
    """Batch over DP; KV heads over TP when divisible by the TP size,
    otherwise the cache SEQUENCE dim is TP-sharded (sequence-parallel KV:
    decode attention then parallelises over cache length — the decode
    bottleneck is cache bandwidth, so this is also the perf-correct
    layout for GQA archs with few KV heads)."""
    if cfg.n_kv_heads % tp_size == 0:
        s = {"k": P(dp, None, TP, None), "v": P(dp, None, TP, None)}
        if _kv_quantized(cfg):
            s.update({"ks": P(dp, None, TP), "vs": P(dp, None, TP)})
        return s
    s = {"k": P(dp, TP, None, None), "v": P(dp, TP, None, None)}
    if _kv_quantized(cfg):
        s.update({"ks": P(dp, TP, None), "vs": P(dp, TP, None)})
    return s


# ---------------------------------------------------------------------------
# MLP (paper eq 6: FFN(x) = act(xW1 + b1)W2 + b2; gated for SiLU-family)
# ---------------------------------------------------------------------------

def mlp_params(cfg, key, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.gated_mlp:
        return {"w_gate": he(ks[0], (d, f), 1.0, dt),
                "w_up": he(ks[1], (d, f), 1.0, dt),
                "w_down": he(ks[2], (f, d), 1.0, dt)}
    p = {"w1": he(ks[0], (d, f), 1.0, dt), "w2": he(ks[1], (f, d), 1.0, dt)}
    if cfg.bias:
        p["b1"] = jnp.zeros((f,), dt)
        p["b2"] = jnp.zeros((d,), dt)
    return p


def mlp_specs(cfg):
    f, t = fsdp_axis(cfg), tp_axis(cfg)
    if cfg.gated_mlp:
        return {"w_gate": P(f, t), "w_up": P(f, t),
                "w_down": P(t, f)}
    s = {"w1": P(f, t), "w2": P(t, f)}
    if cfg.bias:
        s.update({"b1": P(t), "b2": P(None)})
    return s


def apply_mlp(p, x, cfg):
    _health.tap_activation("mlp_in", x, cfg)
    act = approx.activation(cfg.activation, cfg.act_approx,
                            interpret=cfg.kernel_interpret)
    if cfg.gated_mlp:
        gate = act(linear(x, p["w_gate"], "bsd,df->bsf", cfg))
        up = linear(x, p["w_up"], "bsd,df->bsf", cfg)
        return linear((gate * up).astype(x.dtype), p["w_down"],
                      "bsf,fd->bsd", cfg).astype(x.dtype)
    h = linear(x, p["w1"], "bsd,df->bsf", cfg)
    if "b1" in p:
        h = h + p["b1"]
    h = act(h).astype(x.dtype)
    out = linear(h, p["w2"], "bsf,fd->bsd", cfg)
    if "b2" in p:
        out = out + p["b2"]
    return out.astype(x.dtype)
