"""Selective SSM (Mamba-style) + Hymba hybrid block (hymba-1.5b).

Hymba runs attention and SSM heads *in parallel* inside one layer
(arXiv:2411.13676): the block output is the mean of the per-branch
normalised outputs.  The attention half uses a sliding window
(cfg.sliding_window), giving the sub-quadratic long_500k path together
with the O(1)-state mamba half.

The selective scan uses the same chunked log-space-exact formulation as
rwkv.py (diff-tensor inside the chunk, ``lax.scan`` across chunks,
while-free ``mamba_chunk_body`` exported for dry-run costing).

Technique hooks: SiLU / softplus run through the bounded-domain LUT path
when cfg.act_approx != "exact" (DESIGN.md §3); attention softmax through
``approx.masked_softmax``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import approx
from repro.models import layers as L

CHUNK = 16


def mamba_params(cfg, key):
    d = cfg.d_model                  # d_inner == d_model (parallel-head budget)
    n = cfg.ssm_state
    dt_rank = cfg.dt_rank or max(d // 16, 1)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": L.he(ks[0], (d, 2 * d), 1.0, dt),
        "conv_w": L.he(ks[1], (cfg.conv_width, d), 1.0, jnp.float32),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "x_proj": L.he(ks[2], (d, dt_rank + 2 * n), 1.0, dt),
        "dt_proj": L.he(ks[3], (dt_rank, d), 1.0, jnp.float32),
        "dt_bias": jnp.full((d,), -4.0, jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d, 1))),
        "D": jnp.ones((d,), jnp.float32),
        "out_proj": L.he(ks[4], (d, d), 1.0, dt),
    }


def mamba_specs(cfg):
    return {"in_proj": P(L.FSDP, L.TP), "conv_w": P(None, L.TP),
            "conv_b": P(L.TP), "x_proj": P(L.TP, None),
            "dt_proj": P(None, L.TP), "dt_bias": P(L.TP),
            "A_log": P(L.TP, None), "D": P(L.TP),
            "out_proj": P(L.TP, L.FSDP)}


def mamba_chunk_body(h, chunk, A=None):
    """One chunk of the selective scan.  While-free; exported for costing.

    h [B,D,N]; chunk = dict(la, dbx [B,c,D,N], C [B,c,N])  — or, to avoid
    materialising [B,S,D,N] over the whole sequence (measured 27 GB/device
    at hymba prefill_32k), dict(delta, xin [B,c,D], bt, C [B,c,N]) with A
    [D,N], from which la/dbx are built per chunk.
    Returns (h_new, y [B,c,D]).
    """
    if "la" in chunk:
        la, dbx, C = chunk["la"], chunk["dbx"], chunk["C"]
    else:
        delta, xin, bt, C = (chunk["delta"], chunk["xin"], chunk["bt"],
                             chunk["C"])
        la = delta[..., None] * A[None, None]                # [B,c,D,N]
        dbx = (delta * xin)[..., None] * bt[:, :, None, :]
    cum = jnp.cumsum(la, axis=1)                        # inclusive [B,c,D,N]
    # inter: y_t += C_t . (e^{cum_t} (.) h)
    y = jnp.einsum("btn,btdn,bdn->btd", C, jnp.exp(cum), h)
    # intra: exact pairwise decay, inclusive lower triangle (j <= t)
    c = la.shape[1]
    diff = cum[:, :, None] - cum[:, None, :]            # [B,c,c,D,N]
    tri = jnp.tril(jnp.ones((c, c), bool))[None, :, :, None, None]
    w = jnp.where(tri, jnp.exp(diff), 0.0)
    y = y + jnp.einsum("btn,bjdn,btjdn->btd", C, dbx, w)
    total = cum[:, -1:]                                 # [B,1,D,N]
    h_new = (jnp.exp(total[:, 0]) * h
             + jnp.einsum("bjdn->bdn", dbx * jnp.exp(total - cum)))
    return h_new, y


def ssm_scan(delta, xin, bt, C, A, h0):
    """delta/xin [B,S,D], bt/C [B,S,N], A [D,N] -> y [B,S,D], h_final.

    Arbitrary S: full chunks via ``lax.scan``, remainder direct.  The
    [B,c,D,N] decay tensors are built per chunk inside the body so the
    whole-sequence [B,S,D,N] tensor never exists.
    """
    b, s, d = delta.shape
    n = bt.shape[-1]
    main = (s // CHUNK) * CHUNK
    h = h0
    parts = []

    def chunkify(a, nc):
        return a[:, :main].reshape((b, nc, CHUNK) + a.shape[2:]) \
            .transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))

    if main:
        nc = main // CHUNK
        xs = {"delta": chunkify(delta, nc), "xin": chunkify(xin, nc),
              "bt": chunkify(bt, nc), "C": chunkify(C, nc)}

        def body(h, chunk):
            h, y = mamba_chunk_body(h, chunk, A)
            return h, y

        h, ys = jax.lax.scan(body, h, xs)               # ys [nc,B,c,D]
        parts.append(ys.transpose(1, 0, 2, 3).reshape(b, main, d))
    if s > main:
        h, y = mamba_chunk_body(
            h, {"delta": delta[:, main:], "xin": xin[:, main:],
                "bt": bt[:, main:], "C": C[:, main:]}, A)
        parts.append(y)
    y = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return y, h


def ssm_naive(la, dbx, C, h0):
    """Token-at-a-time oracle for tests."""
    def step(h, inp):
        la_t, dbx_t, c_t = inp
        h = jnp.exp(la_t) * h + dbx_t
        return h, jnp.einsum("bn,bdn->bd", c_t, h)

    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (la, dbx, C))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h


def apply_mamba(p, x, cfg, state):
    """x [B,S,D]; state = dict(h [B,D,N], conv [B,K-1,D])."""
    b, s, d = x.shape
    n = cfg.ssm_state
    kw = cfg.conv_width
    xz = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv as kw shifted adds
    xpad = jnp.concatenate([state["conv"].astype(xin.dtype), xin], axis=1)
    conv = sum(xpad[:, i:i + s] * p["conv_w"][i] for i in range(kw)) + p["conv_b"]
    new_conv = xpad[:, -(kw - 1):] if kw > 1 else state["conv"]
    xc = approx.silu(conv, mode=cfg.act_approx).astype(x.dtype)
    dbn = jnp.einsum("bsd,df->bsf", xc, p["x_proj"]).astype(jnp.float32)
    dt_rank = p["dt_proj"].shape[0]
    dtr, B_t, C_t = jnp.split(dbn, [dt_rank, dt_rank + n], axis=-1)
    delta = approx.softplus(dtr @ p["dt_proj"] + p["dt_bias"], mode=cfg.act_approx)
    A = -jnp.exp(p["A_log"])                            # [D,N]
    y, h = ssm_scan(delta, xc.astype(jnp.float32), B_t, C_t, A, state["h"])
    y = y + p["D"] * xc.astype(jnp.float32)
    y = y * approx.silu(z.astype(jnp.float32), mode=cfg.act_approx)
    out = jnp.einsum("bsd,df->bsf", y.astype(x.dtype), p["out_proj"])
    return out, {"h": h, "conv": new_conv.astype(jnp.dtype(cfg.dtype))}


def init_mamba_state(cfg, batch):
    d, n, kw = cfg.d_model, cfg.ssm_state, cfg.conv_width
    return {"h": jnp.zeros((batch, d, n), jnp.float32),
            "conv": jnp.zeros((batch, kw - 1, d), jnp.dtype(cfg.dtype))}


def mamba_state_specs(cfg, dp=("data",)):
    return {"h": P(dp, L.TP, None), "conv": P(dp, None, L.TP)}


# ---------------------------------------------------------------------------
# Hymba hybrid block: parallel attention + mamba heads
# ---------------------------------------------------------------------------

def block_params(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.norm_params(cfg), "ln2": L.norm_params(cfg),
            "attn": L.attention_params(cfg, k1),
            "mamba": mamba_params(cfg, k2),
            "out_norm_a": jnp.ones((cfg.d_model,), jnp.float32),
            "out_norm_m": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": L.mlp_params(cfg, k3)}


def block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg),
            "attn": L.attention_specs(cfg), "mamba": mamba_specs(cfg),
            "out_norm_a": P(None), "out_norm_m": P(None),
            "mlp": L.mlp_specs(cfg)}


def _rmsn(x, scale):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True)
                               + 1e-6) * scale).astype(x.dtype)


def apply_block(bp, x, cfg, state, *, positions, cache_index=None,
                kv_len_valid=None, ring=False):
    """state = dict(mamba=..., kv=ring cache or None)."""
    h = L.apply_norm(bp["ln1"], x, cfg)
    a, new_kv = L.apply_attention(bp["attn"], h, cfg, positions=positions,
                                  cache=state.get("kv"),
                                  cache_index=cache_index,
                                  kv_len_valid=kv_len_valid,
                                  causal=not ring)
    m, new_ms = apply_mamba(bp["mamba"], h, cfg, state["mamba"])
    y = 0.5 * (_rmsn(a, bp["out_norm_a"]) + _rmsn(m, bp["out_norm_m"]))
    x = x + y
    h = L.apply_norm(bp["ln2"], x, cfg)
    x = x + L.apply_mlp(bp["mlp"], h, cfg)
    new_state = {"mamba": new_ms}
    if new_kv is not None:
        new_state["kv"] = new_kv
    return x, new_state
