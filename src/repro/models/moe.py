"""Mixture-of-Experts block (granite-moe 40e top-8, deepseek-moe 2+64e top-6).

Dispatch design (DESIGN.md §3): *group-limited capacity* routing executed
under ``shard_map`` — every (data, model) device owns one data-shard's
tokens and one expert slice, so the capacity scatter, the expert FFN and
the combine gather are all device-LOCAL; a single psum over the EP
('model') axis merges the per-slice partial outputs.  GSPMD cannot
partition the token<->expert scatter on its own (measured: 25.8 GB/device
replicated dispatch arrays); explicit locality is the fix — and it is also
the honest EP communication pattern (the psum is the combine all-reduce).

Expert count is padded to a multiple of the EP axis (padded experts are
never routed to).  Expert weights are EP-sharded and replicated over
'data' (experts are fine-grained and small; the memory table in DESIGN.md
shows this fits with int8 optimizer moments).

The router softmax goes through ``approx.softmax`` — under the paper's
technique the router, too, runs on the LUT pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import approx
from repro.models import layers as L

EP_PAD = 16   # pad expert count to a multiple of the EP ('model') axis


def padded_experts(cfg) -> int:
    return -(-cfg.n_experts // EP_PAD) * EP_PAD


def moe_params(cfg, key):
    E, D, Fe = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    Ep = padded_experts(cfg)     # pjit needs the EP dim divisible by 'model';
    dt = jnp.dtype(cfg.dtype)    # padded experts never receive tokens.
    ks = jax.random.split(key, 5)
    p = {
        "router": L.he(ks[0], (D, E), 1.0, jnp.float32),
        "w_gate": L.he(ks[1], (Ep, D, Fe), 1.0, dt),
        "w_up": L.he(ks[2], (Ep, D, Fe), 1.0, dt),
        "w_down": L.he(ks[3], (Ep, Fe, D), 1.0, dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_params(cfg, ks[4],
                                   d_ff=cfg.n_shared_experts * Fe)
    return p


def moe_specs(cfg):
    s = {
        "router": P(None, None),
        # EP over 'model' x FSDP over 'data' on the d_model dim; the
        # shard_map dispatch all-gathers its expert slice over 'data'
        # just-in-time (ZeRO-3 style)
        "w_gate": P(L.TP, L.FSDP, None),
        "w_up": P(L.TP, L.FSDP, None),
        "w_down": P(L.TP, None, L.FSDP),
    }
    if cfg.n_shared_experts:
        s["shared"] = L.mlp_specs(cfg)
    return s


def _capacity(T: int, cfg) -> int:
    c = int(np.ceil(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def _route(xt, router, cfg):
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = approx.softmax(logits, axis=-1, mode=cfg.softmax_mode,
                           interpret=cfg.kernel_interpret)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)          # [T,k]
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx


def _expert_ffn(buf, wg, wu, wd, cfg):
    act = approx.activation(cfg.activation, cfg.act_approx,
                            interpret=cfg.kernel_interpret)
    g = act(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", (g * u).astype(buf.dtype), wd)


def _dispatch_ffn_combine(xt, gates, idx, wg, wu, wd, cfg, *, e_lo, e_n, C):
    """Local token->expert scatter, FFN, gather-back for experts
    [e_lo, e_lo+e_n).  All shapes local; no collectives."""
    T, D = xt.shape
    k = cfg.top_k
    fid = idx.reshape(-1)
    mine = jnp.logical_and(fid >= e_lo, fid < e_lo + e_n)
    lid = jnp.clip(fid - e_lo, 0, e_n - 1)
    onehot = jnp.where(mine[:, None],
                       jax.nn.one_hot(lid, e_n, dtype=jnp.int32), 0)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              lid[:, None], axis=1)[:, 0]
    keep = jnp.logical_and(mine, pos < C)
    src = jnp.repeat(xt, k, axis=0)
    buf = jnp.zeros((e_n, C, D), xt.dtype)
    buf = buf.at[lid, jnp.clip(pos, 0, C - 1)].add(
        jnp.where(keep[:, None], src, 0), mode="drop")
    y = _expert_ffn(buf, wg, wu, wd, cfg)
    got = y[lid, jnp.clip(pos, 0, C - 1)]
    got = jnp.where(keep[:, None], got, 0)
    return jnp.sum(got.reshape(T, k, D)
                   * gates.reshape(T, k, 1).astype(xt.dtype), axis=1)


def apply_moe(p, x, cfg):
    from repro.dist import ctx
    B, S, D = x.shape
    T = B * S
    Ep = padded_experts(cfg)
    xt = x.reshape(T, D)

    if not ctx._mesh_active():
        gates, idx = _route(xt, p["router"], cfg)
        out = _dispatch_ffn_combine(
            xt, gates, idx, p["w_gate"], p["w_up"], p["w_down"], cfg,
            e_lo=0, e_n=Ep, C=_capacity(T, cfg))
    else:
        from jax.interpreters.pxla import thread_resources
        mesh = thread_resources.env.physical_mesh
        dp = ctx.dp_axes()
        tp = mesh.shape["model"]
        dp_total = 1
        for a in (dp or ()):
            dp_total *= mesh.shape[a]
        e_n = Ep // tp
        C = _capacity(T // dp_total, cfg)   # group-limited capacity

        def local(xt, router, wg, wu, wd):
            m = jax.lax.axis_index("model")
            # ZeRO-3: gather the FSDP'd d_model dim of my expert slice
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
            gates, idx = _route(xt, router, cfg)
            out = _dispatch_ffn_combine(
                xt, gates, idx, wg, wu, wd, cfg,
                e_lo=m * e_n, e_n=e_n, C=C)
            return jax.lax.psum(out, "model")

        out = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, None), P(None, None),
                      P("model", "data", None), P("model", "data", None),
                      P("model", None, "data")),
            out_specs=P(dp, None),
            check_vma=False,
        )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:
        out = out + L.apply_mlp(p["shared"], x, cfg).reshape(T, D)
    return out.reshape(B, S, D).astype(x.dtype)


def load_balance_loss(logits: jnp.ndarray, idx: jnp.ndarray, cfg) -> jnp.ndarray:
    """Switch-style auxiliary load-balancing loss (exposed for training)."""
    E = cfg.n_experts
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E), axis=0)
    return E * jnp.sum(me * ce)
