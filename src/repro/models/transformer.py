"""Unified decoder-only LM assembly for all LM-family architectures.

Families: "dense" (granite-8b, internlm2, qwen2.5, nemotron, chameleon),
"moe" (granite-moe, deepseek-moe), "rwkv" (rwkv6-3b), "hybrid" (hymba).
Block math lives in layers.py / moe.py / rwkv.py / ssm.py; this module owns
embedding, layer stacking (lax.scan + per-layer remat), the LM head, loss,
and the prefill/decode state machines.

Decode state ("cache") per family:
  dense/moe : stacked KV caches [L,B,S,KV,Dh] + position index
  rwkv      : stacked recurrence states (S [L,B,H,Dk,Dv], token-shift tails)
  hybrid    : stacked mamba states + *ring-buffer* sliding-window KV caches
              [L,B,W,KV,Dh] (the sub-quadratic long_500k path)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import ctx
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv as R
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# Per-family block param/spec builders
# ---------------------------------------------------------------------------

def block_params(cfg, key):
    if cfg.family in ("dense", "moe"):
        k1, k2 = jax.random.split(key)
        p = {"ln1": L.norm_params(cfg), "ln2": L.norm_params(cfg),
             "attn": L.attention_params(cfg, k1)}
        if cfg.family == "moe":
            p["moe"] = M.moe_params(cfg, k2)
        else:
            p["mlp"] = L.mlp_params(cfg, k2)
        return p
    if cfg.family == "rwkv":
        return R.block_params(cfg, key)
    if cfg.family == "hybrid":
        return S.block_params(cfg, key)
    raise ValueError(cfg.family)


def block_specs(cfg):
    if cfg.family in ("dense", "moe"):
        s = {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg),
             "attn": L.attention_specs(cfg)}
        if cfg.family == "moe":
            s["moe"] = M.moe_specs(cfg)
        else:
            s["mlp"] = L.mlp_specs(cfg)
        return s
    if cfg.family == "rwkv":
        return R.block_specs(cfg)
    if cfg.family == "hybrid":
        return S.block_specs(cfg)
    raise ValueError(cfg.family)


def apply_block(bp, x, cfg, state, *, positions, cache_index=None,
                kv_len_valid=None, ring=False):
    """Dispatch one block.  state is the per-layer decode state (or None
    for stateless attention training; rwkv/hybrid always carry state)."""
    if cfg.family in ("dense", "moe"):
        if cfg.post_norm:
            a, nc = L.apply_attention(bp["attn"], x, cfg, positions=positions,
                                      cache=state, cache_index=cache_index,
                                      kv_len_valid=kv_len_valid, causal=not ring)
            x = L.apply_norm(bp["ln1"], x + a, cfg)
            f = (M.apply_moe(bp["moe"], x, cfg) if cfg.family == "moe"
                 else L.apply_mlp(bp["mlp"], x, cfg))
            return L.apply_norm(bp["ln2"], x + f, cfg), nc
        h = ctx.unshard_seq(L.apply_norm(bp["ln1"], x, cfg))
        a, nc = L.apply_attention(bp["attn"], h, cfg, positions=positions,
                                  cache=state, cache_index=cache_index,
                                  kv_len_valid=kv_len_valid, causal=not ring)
        x = x + a
        h = ctx.unshard_seq(L.apply_norm(bp["ln2"], x, cfg))
        f = (M.apply_moe(bp["moe"], h, cfg) if cfg.family == "moe"
             else L.apply_mlp(bp["mlp"], h, cfg))
        return x + f, nc
    if cfg.family == "rwkv":
        return R.apply_block(bp, x, cfg, state)
    if cfg.family == "hybrid":
        return S.apply_block(bp, x, cfg, state, positions=positions,
                             cache_index=cache_index,
                             kv_len_valid=kv_len_valid, ring=ring)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Whole-model params
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    ke, kl, kf = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    blocks = jax.vmap(lambda k: block_params(cfg, k))(
        jax.random.split(kl, cfg.n_layers))
    p = {
        "embed": L.he(ke, (cfg.padded_vocab, cfg.d_model), 1.0, dt),
        "blocks": blocks,
        "ln_f": L.norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.he(kf, (cfg.d_model, cfg.padded_vocab), 1.0, dt)
    return p


def _stack(spec_tree):
    return jax.tree.map(lambda spec: P(*((None,) + tuple(spec))), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg):
    s = {
        # embed sharded on d_model (clean gather); head stays vocab-parallel
        "embed": P(None, L.FSDP),
        "blocks": _stack(block_specs(cfg)),
        "ln_f": L.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = P(L.FSDP, L.TP)  # vocab-parallel logits
    return s


# ---------------------------------------------------------------------------
# Layer stacking
# ---------------------------------------------------------------------------

def _fresh_state(cfg, batch):
    """Zero recurrent state used inside a training step (rwkv/hybrid)."""
    if cfg.family == "rwkv":
        return R.init_layer_state(cfg, batch)
    if cfg.family == "hybrid":
        return {"mamba": S.init_mamba_state(cfg, batch)}
    return None


def _scan_blocks(params, x, cfg, *, positions, states=None, cache_index=None,
                 kv_len_valid=None, ring=False):
    need_state = cfg.family in ("rwkv", "hybrid")
    if states is None and need_state:
        per_layer = _fresh_state(cfg, x.shape[0])
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            per_layer)

    def body(carry, layer_in):
        bp, st = layer_in
        carry = ctx.shard_activations(carry)
        y, new_state = apply_block(bp, carry, cfg, st, positions=positions,
                                   cache_index=cache_index,
                                   kv_len_valid=kv_len_valid, ring=ring)
        return ctx.shard_activations(y), new_state

    f = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, new_states = jax.lax.scan(f, x, (params["blocks"], states))
        return x, new_states
    outs = []
    for i in range(cfg.n_layers):
        bp = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
        st = None if states is None else jax.tree.map(
            lambda a, i=i: a[i], states)
        x, ns = f(x, (bp, st))
        outs.append(ns)
    if outs[0] is None:
        return x, None
    return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _head(params, x, cfg):
    head = params.get("lm_head")
    if head is None:
        # tied embeddings: contract on the table's LAST axis (no explicit
        # .T so stored-integer tables route through L.linear untransposed;
        # per-channel exponents on the contraction axis fall back to the
        # float-view path inside linear — the documented tied-head case)
        logits = L.linear(x, params["embed"], "...d,vd->...v", cfg)
    else:
        logits = L.linear(x, head, "...d,dv->...v", cfg)
    if cfg.padded_vocab != cfg.vocab_size:   # mask pad ids
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def forward(params, tokens, cfg, *, positions=None):
    """tokens [B,S] -> logits [B,S,V] (teacher-forced / no cache)."""
    b, s = tokens.shape
    x = L.embed_rows(params["embed"], tokens,
                     gather=ctx.embed_lookup).astype(jnp.dtype(cfg.dtype))
    x = ctx.shard_activations(x)
    positions = jnp.arange(s) if positions is None else positions
    x, _ = _scan_blocks(params, x, cfg, positions=positions)
    x = L.apply_norm(params["ln_f"], x, cfg)
    return ctx.shard_logits(_head(params, x, cfg))


def loss_fn(params, batch, cfg):
    """Next-token cross-entropy, f32 logsumexp, mean over tokens."""
    logits = forward(params, batch["tokens"], cfg).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Decode state machines
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch, max_len):
    idx = jnp.zeros((), jnp.int32)
    if cfg.family in ("dense", "moe"):
        per = L.init_kv_cache(cfg, batch, max_len)
    elif cfg.family == "rwkv":
        per = R.init_layer_state(cfg, batch)
    elif cfg.family == "hybrid":
        per = {"mamba": S.init_mamba_state(cfg, batch),
               "kv": L.init_kv_cache(cfg, batch,
                                     min(max_len, cfg.sliding_window))}
    else:
        raise ValueError(cfg.family)
    layers = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), per)
    return {"layers": layers, "index": idx}


def decode_state_specs(cfg, dp=("data",), tp_size=16):
    if cfg.family in ("dense", "moe"):
        per = L.kv_cache_specs(cfg, dp, tp_size)
    elif cfg.family == "rwkv":
        per = R.state_specs(cfg, dp)
    elif cfg.family == "hybrid":
        per = {"mamba": S.mamba_state_specs(cfg, dp),
               "kv": L.kv_cache_specs(cfg, dp, tp_size)}
    else:
        raise ValueError(cfg.family)
    return {"layers": _stack(per), "index": P()}


def prefill(params, tokens, cfg, state):
    """Prompt pass filling the decode state; returns (last_logits, state).

    dense/moe: writes the whole prompt into the KV cache.
    rwkv:      runs the recurrence, final state is the cache.
    hybrid:    runs banded attention + SSM; the serve driver chunks prompts
               through the cached path W tokens at a time (ring cache), so
               this entry handles prompt_len <= sliding_window directly.
    """
    b, s = tokens.shape
    x = L.embed_rows(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    idx = state["index"]
    per_lane = getattr(idx, "ndim", 0) == 1      # [B] vector (repro.cell)
    if cfg.family in ("dense", "moe"):
        if per_lane:
            # continuous-batching decode: every lane sits at its own depth.
            # Cache writes scatter at [lane, idx[lane]]; positions and the
            # validity bound are per-lane (layers._sdpa_block broadcasts).
            assert s == 1, "per-lane decode state advances one token at " \
                "a time; joins prefill a fresh state and merge " \
                "(cell.scheduler)"
            positions = idx[:, None] + jnp.arange(s)
        else:
            positions = idx + jnp.arange(s)
        x, new_layers = _scan_blocks(params, x, cfg, positions=positions,
                                     states=state["layers"], cache_index=idx,
                                     kv_len_valid=idx + s)
    elif cfg.family == "rwkv":
        x, new_layers = _scan_blocks(params, x, cfg, positions=None,
                                     states=state["layers"])
    else:  # hybrid
        assert not per_lane, \
            "per-lane decode indices cover dense/moe/rwkv; hybrid ring " \
            "caches keep the shared-cursor path"
        w = cfg.sliding_window
        positions = idx + jnp.arange(s)
        if s > w:
            # long prompt: banded attention, no cache fill (the serve
            # driver chunks real prompts through the ring path W at a time)
            st = {"mamba": state["layers"]["mamba"]}
            x, nl = _scan_blocks(params, x, cfg, positions=positions,
                                 states=st)
            new_layers = {"mamba": nl["mamba"], "kv": state["layers"]["kv"]}
        else:
            # s == 1: true ring decode (slots may be rotated -> positional
            # causality meaningless; validity mask only).  s > 1: prompt
            # chunk with monotone slots (serve driver aligns chunks so
            # idx + s <= W) -> ordinary causal masking applies.
            x, new_layers = _scan_blocks(
                params, x, cfg, positions=positions, states=state["layers"],
                cache_index=jnp.mod(idx, w),
                kv_len_valid=jnp.minimum(idx + s, w), ring=(s == 1))
    x = L.apply_norm(params["ln_f"], x, cfg)
    logits = _head(params, x[:, -1], cfg)
    return logits, {"layers": new_layers, "index": idx + s}


def decode_step(params, token, cfg, state):
    """One new token [B] against the running state -> (logits [B,V], state).

    ``state["index"]`` may be the usual shared scalar, or a per-lane [B]
    vector (the ``repro.cell`` continuous-batching path: lanes decode at
    heterogeneous depths, cache writes scatter per lane)."""
    return prefill(params, token[:, None], cfg, state)


def merge_decode_state(old, new, lane_mask):
    """Per-lane select between two same-shaped decode states.

    The join half of continuous batching (cell.scheduler): freshly
    prefilled lanes take ``new``'s cache/recurrence and index, resident
    lanes keep ``old``'s — no drain barrier.  Every ``layers`` leaf is
    stacked ``[n_layers, B, ...]`` (batch at axis 1); ``index`` may be
    scalar on either side and merges to a per-lane [B] vector.
    """
    def sel(n, o):
        m = lane_mask.reshape((1, lane_mask.shape[0]) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)

    index = jnp.where(lane_mask,
                      jnp.broadcast_to(new["index"], lane_mask.shape),
                      jnp.broadcast_to(old["index"], lane_mask.shape))
    return {"layers": jax.tree.map(sel, new["layers"], old["layers"]),
            "index": index}


def forward_no_blocks(params, tokens, cfg):
    """Embed -> final norm -> head only (dry-run cost decomposition)."""
    x = L.embed_rows(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = L.apply_norm(params["ln_f"], x, cfg)
    return _head(params, x, cfg)
