"""Whisper-style encoder-decoder (whisper-large-v3 backbone).

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, enc_seq, d_model].  Encoder: pre-norm
bidirectional self-attention blocks + GELU MLPs (the paper's LUT-GELU is a
direct hit here) + final LayerNorm.  Decoder: causal self-attention (KV
cache), cross-attention to the encoder memory (cross-KV cached at prefill),
GELU MLP, tied output head.  Sinusoidal positions (no rope).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import ctx
from repro.models import layers as L


def sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def cross_attention_params(cfg, key):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {"wq": L.he(ks[0], (d, h * dh), 1.0, dt),
            "wk": L.he(ks[1], (d, h * dh), 1.0, dt),
            "wv": L.he(ks[2], (d, h * dh), 1.0, dt),
            "wo": L.he(ks[3], (h * dh, d), 1.0, dt),
            "bq": jnp.zeros((h * dh,), dt), "bv": jnp.zeros((h * dh,), dt),
            "bo": jnp.zeros((d,), dt)}


def cross_attention_specs(cfg):
    return {"wq": P(L.FSDP, L.TP), "wk": P(L.FSDP, L.TP),
            "wv": P(L.FSDP, L.TP), "wo": P(L.TP, L.FSDP),
            "bq": P(L.TP), "bv": P(L.TP), "bo": P(None)}


def enc_block_params(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_params(cfg), "ln2": L.norm_params(cfg),
            "attn": L.attention_params(cfg, k1),
            "mlp": L.mlp_params(cfg, k2)}


def enc_block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg),
            "attn": L.attention_specs(cfg), "mlp": L.mlp_specs(cfg)}


def dec_block_params(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.norm_params(cfg), "ln2": L.norm_params(cfg),
            "ln3": L.norm_params(cfg),
            "self_attn": L.attention_params(cfg, k1),
            "cross_attn": cross_attention_params(cfg, k2),
            "mlp": L.mlp_params(cfg, k3)}


def dec_block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg),
            "ln3": L.norm_specs(cfg),
            "self_attn": L.attention_specs(cfg),
            "cross_attn": cross_attention_specs(cfg),
            "mlp": L.mlp_specs(cfg)}


def init_params(cfg, key):
    ke, k1, k2, kf = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    enc = jax.vmap(lambda k: enc_block_params(cfg, k))(
        jax.random.split(k1, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: dec_block_params(cfg, k))(
        jax.random.split(k2, cfg.n_layers))
    return {"embed": L.he(ke, (cfg.padded_vocab, cfg.d_model), 1.0, dt),
            "enc_blocks": enc, "dec_blocks": dec,
            "ln_enc": L.norm_params(cfg), "ln_dec": L.norm_params(cfg)}


def _mask_pad(logits, cfg):
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits


def _stack(tree):
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg):
    return {"embed": P(None, L.FSDP),
            "enc_blocks": _stack(enc_block_specs(cfg)),
            "dec_blocks": _stack(dec_block_specs(cfg)),
            "ln_enc": L.norm_specs(cfg), "ln_dec": L.norm_specs(cfg)}


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def apply_cross_attention(p, x, cfg, *, memory=None, mem_kv=None):
    """x [B,Sq,D]; memory [B,Sk,D] or precomputed mem_kv (decode cache)."""
    b, sq, d = x.shape
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    q = (jnp.einsum("bsd,df->bsf", x, p["wq"]) + p["bq"]).reshape(b, sq, h, dh)
    if mem_kv is None:
        k = jnp.einsum("bsd,df->bsf", memory, p["wk"])
        v = jnp.einsum("bsd,df->bsf", memory, p["wv"]) + p["bv"]
        sk = memory.shape[1]
        k = k.reshape(b, sk, h, dh)
        v = v.reshape(b, sk, h, dh)
        mem_kv = {"k": k, "v": v}
    out = L.sdpa(q, mem_kv["k"], mem_kv["v"], cfg, q_offset=0,
                 kv_len_valid=None, causal=False)
    out = jnp.einsum("bsf,fd->bsd", out.reshape(b, sq, h * dh), p["wo"])
    return (out + p["bo"]).astype(x.dtype), mem_kv


def apply_enc_block(bp, x, cfg):
    h = L.apply_norm(bp["ln1"], x, cfg)
    a, _ = L.apply_attention(bp["attn"], h, cfg,
                             positions=jnp.arange(x.shape[1]), causal=False)
    x = x + a
    return x + L.apply_mlp(bp["mlp"], L.apply_norm(bp["ln2"], x, cfg), cfg)


def apply_dec_block(bp, x, cfg, *, positions, memory=None, state=None,
                    cache_index=None):
    """state = dict(kv=self-cache, cross=mem_kv) or None (teacher-forced)."""
    h = L.apply_norm(bp["ln1"], x, cfg)
    a, new_kv = L.apply_attention(
        bp["self_attn"], h, cfg, positions=positions,
        cache=None if state is None else state["kv"], cache_index=cache_index)
    x = x + a
    h = L.apply_norm(bp["ln2"], x, cfg)
    c, mem_kv = apply_cross_attention(
        bp["cross_attn"], h, cfg, memory=memory,
        mem_kv=None if state is None else state.get("cross"))
    x = x + c
    x = x + L.apply_mlp(bp["mlp"], L.apply_norm(bp["ln3"], x, cfg), cfg)
    new_state = None if state is None else {"kv": new_kv, "cross": mem_kv}
    return x, new_state


def _scan(f, x, xs, cfg):
    body = jax.checkpoint(f) if cfg.remat else f
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a, i=i: a[i], xs))
        outs.append(y)
    ys = None if outs[0] is None else jax.tree.map(
        lambda *z: jnp.stack(z), *outs)
    return x, ys


def encode(params, frames, cfg):
    """frames [B,Senc,D] (stub frontend output) -> memory [B,Senc,D]."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)

    def body(carry, bp):
        return ctx.shard_activations(apply_enc_block(
            bp, ctx.shard_activations(carry), cfg)), None

    x, _ = _scan(body, x, params["enc_blocks"], cfg)
    return L.apply_norm(params["ln_enc"], x, cfg)


def decode_train(params, memory, tokens, cfg):
    """Teacher-forced decoder pass -> logits [B,S,V]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(jnp.arange(s), cfg.d_model).astype(x.dtype)

    def body(carry, bp):
        y, _ = apply_dec_block(bp, ctx.shard_activations(carry), cfg,
                               positions=jnp.arange(s), memory=memory)
        return ctx.shard_activations(y), None

    x, _ = _scan(body, x, params["dec_blocks"], cfg)
    x = L.apply_norm(params["ln_dec"], x, cfg)
    return ctx.shard_logits(_mask_pad(
        jnp.einsum("bsd,vd->bsv", x, params["embed"]), cfg))   # tied head


def loss_fn(params, batch, cfg):
    memory = encode(params, batch["frames"], cfg)
    logits = decode_train(params, memory, batch["tokens"], cfg).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch, max_len):
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    per = {"kv": L.init_kv_cache(cfg, batch, max_len),
           "cross": {"k": jnp.zeros((batch, cfg.enc_seq, h, dh), dt),
                     "v": jnp.zeros((batch, cfg.enc_seq, h, dh), dt)}}
    layers = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), per)
    return {"layers": layers, "index": jnp.zeros((), jnp.int32)}


def decode_state_specs(cfg, dp=("data",), tp_size=16):
    # cross-KV stays DP-sharded/TP-replicated: enc_seq=1500 and 20 heads
    # both resist a 16-way split; 1.6 GB/device total is acceptable.
    per = {"kv": L.kv_cache_specs(cfg, dp, tp_size),
           "cross": {"k": P(dp, None, None, None),
                     "v": P(dp, None, None, None)}}
    return {"layers": _stack(per), "index": P()}


def prefill(params, frames, tokens, cfg, state):
    """Encode audio, fill cross-KV, then run prompt tokens."""
    memory = encode(params, frames, cfg)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    idx = state["index"]
    x = x + sinusoid(idx + jnp.arange(s), cfg.d_model).astype(x.dtype)

    def body(carry, layer_in):
        bp, st = layer_in
        y, ns = apply_dec_block(bp, carry, cfg, positions=idx + jnp.arange(s),
                                memory=memory,
                                state={"kv": st["kv"], "cross": None},
                                cache_index=idx)
        return y, ns

    x, new_layers = _scan(body, x, (params["dec_blocks"], state["layers"]), cfg)
    x = L.apply_norm(params["ln_dec"], x, cfg)
    logits = _mask_pad(jnp.einsum("bd,vd->bv", x[:, -1], params["embed"]), cfg)
    return logits, {"layers": new_layers, "index": idx + s}


def decode_step(params, token, cfg, state):
    """One decoder token against self-KV + cached cross-KV."""
    b = token.shape[0]
    idx = state["index"]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(idx + jnp.arange(1), cfg.d_model).astype(x.dtype)

    def body(carry, layer_in):
        bp, st = layer_in
        y, ns = apply_dec_block(bp, carry, cfg, positions=idx + jnp.arange(1),
                                state=st, cache_index=idx)
        return y, ns

    x, new_layers = _scan(body, x, (params["dec_blocks"], state["layers"]), cfg)
    x = L.apply_norm(params["ln_dec"], x, cfg)
    logits = _mask_pad(jnp.einsum("bd,vd->bv", x[:, -1], params["embed"]), cfg)
    return logits, {"layers": new_layers, "index": idx + 1}