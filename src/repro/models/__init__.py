"""Model zoo.  Submodules resolve lazily (PEP 562) so that
``from repro.models import kwt`` — the paper's actual model — never drags
in the dist-dependent LM stack (transformer/encdec/moe) and its heavier
import chain."""

import importlib

_SUBMODULES = ("encdec", "kwt", "layers", "moe", "rwkv", "ssm", "transformer")


def __getattr__(name):
    if name in _SUBMODULES:
        module = importlib.import_module(f"repro.models.{name}")
        globals()[name] = module
        return module
    raise AttributeError(f"module 'repro.models' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
