from repro.models import encdec, kwt, layers, moe, rwkv, ssm, transformer  # noqa: F401
