"""Collapse a trained QAT state into the deployable artifact.

``export(params, spec, qstate)`` freezes the learned weight exponent into
a ``QuantRecipe`` and quantises the float shadow weights through it —
exactly what ``runtime.compile_model(cfg, params, backend="lut",
recipe=...)`` would do at plan time.  Because the QAT forward ran
``po2_fake_quant`` (the recipe's own cast) the whole way, the contract is
**bit-identity**: :func:`eval_forward` logits == the exported engine's
logits, array_equal, not allclose (tests/test_qat.py; the PR's acceptance
criterion).

The exported ``QATExport`` serialises: ``recipe.to_dict()`` round-trips
through JSON and ``qparams`` is the int8 QTensor tree (the ROM image a
real device would flash, plus its byte accounting).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.qat import fakequant
from repro.qat.train import QATSpec
from repro.runtime.recipe import QuantRecipe

Pytree = Any


@dataclasses.dataclass
class QATExport:
    """The train->deploy handoff: float shadow weights + the recipe that
    turns them into the deployed int8 form.

    Deploy with ``runtime.compile_model(cfg, ex.params, backend="lut",
    recipe=ex.recipe)`` (or any quantising backend); ``ex.qparams`` /
    ``ex.quantized_bytes`` are the int8 artifact and its footprint.
    """

    recipe: QuantRecipe
    params: Pytree                 # float shadow weights (engine input)
    qparams: Pytree                # QTensor tree (int8 deploy artifact)
    quantized_bytes: tuple        # (int bytes, residual float bytes)

    @property
    def deployed_params(self) -> Pytree:
        """The float tree the engine actually runs (PTQ round-trip)."""
        return quant.dequantize_tree(self.qparams)

    def recipe_json(self) -> str:
        return json.dumps(self.recipe.to_dict(), indent=2)


def export(params: Pytree, spec: QATSpec, qstate: dict | None = None
           ) -> QATExport:
    """Freeze a QAT run: learned exponent -> recipe, shadow -> int8."""
    recipe = spec.recipe
    if qstate is not None and spec.config.learn_exponent:
        recipe = recipe.with_(
            weight_exponent=int(qstate["weight_exponent"]))
    qtree = recipe.quantize(params)
    return QATExport(recipe=recipe, params=params, qparams=qtree,
                     quantized_bytes=quant.tree_quantized_bytes(qtree))


def eval_forward(cfg, spec: QATSpec, recipe: QuantRecipe | None = None):
    """The QAT *eval* path: jitted forward through the fake-quant weights
    under the backend's exec config — the program whose logits must be
    bit-identical to the exported engine's.

    The ``optimization_barrier`` between fake-quant and the encoder keeps
    XLA from fusing the quantiser into the model (the PR-2 lesson: fusion
    across that seam makes rounding producer-dependent).
    """
    from repro.launch import steps

    recipe = recipe or spec.recipe
    exec_cfg = spec.exec_cfg(cfg)
    mod = steps.model_module(cfg)

    @jax.jit
    def forward(params, x):
        fq = fakequant.fake_quant_tree(params, recipe)
        fq = jax.lax.optimization_barrier(fq)
        return mod.forward(fq, x, exec_cfg)

    return forward


def save(path: str, ex: QATExport) -> None:
    """Write the deploy artifact: recipe JSON + packed int/float leaves.

    QTensor leaves are written in their STORED form — int8, or the
    nibble-packed uint8 bytes of the shared ``core.quant`` codec for
    ``bits<=4`` recipes — so the .npz is byte-for-byte the ROM image a
    device would flash (``quantized_bytes[0]`` of payload, no float or
    int16 detour).  :func:`load` reverses it exactly.
    """
    import numpy as np

    leaves = jax.tree.leaves(
        ex.qparams, is_leaf=lambda x: isinstance(x, quant.QTensor))
    arrays, meta = {}, []
    for i, leaf in enumerate(leaves):
        if isinstance(leaf, quant.QTensor):
            arrays[f"leaf_{i}_values"] = np.asarray(leaf.values)
            meta.append({"kind": "qtensor", "exponent": leaf.exponent,
                         "bits": leaf.bits,
                         "shape": list(leaf.shape),
                         "per_channel": leaf.axis_exponents is not None})
            if leaf.axis_exponents is not None:
                arrays[f"leaf_{i}_axis_exponents"] = np.asarray(
                    leaf.axis_exponents)
        else:
            arrays[f"leaf_{i}_values"] = np.asarray(leaf)
            meta.append({"kind": "float"})
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump({"recipe": ex.recipe.to_dict(), "leaves": meta,
                   "quantized_bytes": list(ex.quantized_bytes)}, f, indent=2)


def load(path: str, like: Pytree) -> tuple[QuantRecipe, Pytree]:
    """Read a saved artifact back into a packed QTensor tree.

    ``like`` supplies the tree STRUCTURE (e.g. ``kwt.init_params`` or the
    export-time ``qparams``); leaf payloads come from disk in their packed
    form and round-trip exactly — feed the result straight to
    ``runtime.compile_model(cfg, qparams, backend=...)`` (pre-quantised
    trees deploy as-is, no float detour).
    """
    import numpy as np

    with open(path + ".json") as f:
        doc = json.load(f)
    data = np.load(path + ".npz")
    recipe = QuantRecipe.from_dict(doc["recipe"])
    leaves, meta = [], doc["leaves"]
    for i, m in enumerate(meta):
        values = jnp.asarray(data[f"leaf_{i}_values"])
        if m["kind"] == "qtensor":
            axis = jnp.asarray(data[f"leaf_{i}_axis_exponents"]) \
                if m["per_channel"] else None
            bits = m.get("bits", 8)
            leaves.append(quant.QTensor(
                values=values, exponent=int(m["exponent"]),
                axis_exponents=axis, bits=bits,
                logical_shape=tuple(m["shape"]) if bits <= 4 else None))
        else:
            leaves.append(values)
    treedef = jax.tree.structure(
        like, is_leaf=lambda x: isinstance(x, quant.QTensor))
    return recipe, jax.tree.unflatten(treedef, leaves)
