"""Straight-through-estimator fake-quant primitives (paper eq 9 in the loss).

Forward values come from :func:`repro.runtime.recipe.po2_fake_quant` — the
SAME function ``QuantRecipe.quantize`` uses for PTQ — so a QAT forward
pass runs bit-identically the weights the deployed engine will run
(export-parity contract, ``repro.qat.export``).  Backward is *clipped*
STE: the cotangent passes through unchanged where the eq-9 cast did not
saturate and is zeroed where it clipped (saturated weights can only be
recovered by the shrinking shadow value, not by gradient noise —
arXiv:2009.04465 §3).

The exponent argument is traced (f32), so QAT exponent *learning* — the
per-step recalibration of the Table V scale from the live shadow weights
— stays inside one jitted train step (``repro.qat.train``).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime.recipe import QuantRecipe

Pytree = Any


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant(w: jnp.ndarray, exponent: jnp.ndarray,
               recipe: QuantRecipe) -> jnp.ndarray:
    """Quantise-dequantise one weight leaf at ``2^exponent`` (eq 9).

    Forward: bit-identical to ``recipe.with_(weight_exponent=e)
    .apply({w})`` (shared ``po2_fake_quant`` math).  Backward: clipped STE
    on ``w``; ``exponent`` receives a zero cotangent (it is calibrated,
    not descended — power-of-2 scales have no useful gradient).
    """
    fq, _ = recipe.fake_quant_leaf(w, exponent)
    return fq


def _fq_fwd(w, exponent, recipe):
    fq, unsat = recipe.fake_quant_leaf(w, exponent)
    return fq, (unsat, exponent)


def _fq_bwd(recipe, res, g):
    unsat, exponent = res
    return (jnp.where(unsat, g, 0.0).astype(g.dtype),
            jnp.zeros_like(exponent))


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_tree(params: Pytree, recipe: QuantRecipe,
                    exponent=None) -> Pytree:
    """STE fake-quant of a parameter tree.

    Leaf selection mirrors ``QuantRecipe.quantize`` exactly (norms/biases
    stay float, paper §IV); forward values are bit-identical to
    ``recipe.apply(params)``.  ``exponent`` (scalar, possibly traced)
    overrides the recipe's static weight exponent — the QAT
    exponent-learning hook.
    """
    e = jnp.asarray(recipe.weight_exponent if exponent is None else exponent,
                    jnp.float32)

    def one(leaf):
        if not recipe._quantizes(leaf):
            return leaf
        return fake_quant(leaf, e, recipe)

    return jax.tree.map(one, params)


def fake_quant_input(x: jnp.ndarray, recipe: QuantRecipe) -> jnp.ndarray:
    """STE fake-quant of model *inputs* at the Table V input exponent
    (2^5 best row) — optional in QAT (the deployed engines feed float
    features, so matching them means leaving this off; the flag exists
    for studying the paper's static input quantisation under training)."""
    input_recipe = recipe.with_(weight_exponent=recipe.input_exponent,
                                per_channel=False, skip_norm_scales=False)
    return fake_quant(x, jnp.asarray(recipe.input_exponent, jnp.float32),
                      input_recipe)


def calibrate_exponent(params: Pytree, recipe: QuantRecipe) -> jnp.ndarray:
    """Traced analytic no-saturation weight exponent for the current shadow
    weights: largest y with ``floor(max|w| * 2^y)`` unsaturated across all
    quantised leaves (the in-jit counterpart of ``quant.choose_exponent``
    / ``QuantRecipe.calibrated``).  Clipped to [0, 14] so a transient
    all-zero leaf cannot blow the exponent up."""
    hi = 2 ** (recipe.bits - 1) - 1
    exps = [jnp.floor(jnp.log2(
        hi / jnp.maximum(jnp.max(jnp.abs(leaf.astype(jnp.float32))), 1e-30)))
        for leaf in jax.tree.leaves(params) if recipe._quantizes(leaf)]
    if not exps:
        return jnp.asarray(float(recipe.weight_exponent), jnp.float32)
    return jnp.clip(jnp.stack(exps).min(), 0.0, 14.0)
