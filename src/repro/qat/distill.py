"""Knowledge distillation into the quantised student (paper §III route).

The paper's headline shrink — KWT-1 retrained 369x smaller (35 -> 2
classes) with ~10% accuracy loss — is a *retraining* result, and KD is
the strongest retraining signal we can give the quantised student: a
float KWT-1 teacher's soft posteriors carry the inter-class structure the
2-class hard labels throw away (hardware-aware-training line,
arXiv:2009.04465; sub-8-bit KWS QAT, arXiv:2207.06920).

Pieces:

* :func:`teacher_config` — a KWT-1 teacher on the *student's* input grid
  (KD needs a shared input space; depth/width stay KWT-1's).
* :func:`train_teacher` — float teacher training on the n-class surrogate
  task (the synthetic GSC generator is class-count-generic and classes
  {0, 1} coincide distributionally with the student's binary task).
* :func:`reduce_head` — the 35 -> 2 head reduction: the kept keyword
  column becomes student class 1, the remaining columns pool (mean) into
  the background class 0.
* :func:`shrink_teacher` — ablation-driven depth shrink via
  ``tools.surgeon`` (lowest-impact blocks removed first) so the per-step
  KD forward is cheap.
* :class:`DistillSpec` / :func:`make_distill_loss` — the KD loss
  ``(1-alpha)*CE + alpha*T^2*KL(teacher_T || student_T)`` in the shape
  ``steps``' loss contract expects; plugged into the QAT step via
  ``QATSpec(distill=...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import kwt
from repro.optim import adamw

Pytree = Any


def teacher_config(teacher_cfg, student_cfg):
    """The teacher re-gridded onto the student's MFCC input (and float
    execution modes): KD evaluates both models on the same batch."""
    return teacher_cfg.with_(input_dim=student_cfg.input_dim,
                             patch_dim=(student_cfg.input_dim[0], 1),
                             softmax_mode="exact", act_approx="exact")


def train_teacher(tcfg, steps: int, seed: int = 0, batch: int = 64,
                  lr: float = 3e-3, init_params: Pytree | None = None):
    """Float teacher training on the synthetic n-class keyword task.
    ``init_params`` resumes from an existing tree — the retrain half of
    the paper's iterative remove-then-retrain shrink (§III)."""
    from repro.data import pipeline

    hp = adamw.HParams(lr=lr, warmup_steps=max(2, steps // 10),
                       total_steps=max(steps, 10), weight_decay=0.0)
    params = init_params if init_params is not None else \
        kwt.init_params(tcfg, jax.random.PRNGKey(seed))
    state = adamw.init(params, hp)

    @jax.jit
    def step(params, state, b):
        loss, grads = jax.value_and_grad(kwt.loss_fn)(params, b, tcfg)
        params, state, _ = adamw.update(grads, state, params, hp,
                                        scan_stacked=False)
        return params, state, loss

    for i in range(steps):
        b = pipeline.keyword_batch(seed, i, batch=batch,
                                   input_dim=tcfg.input_dim,
                                   n_classes=tcfg.n_classes)
        params, state, _ = step(params, state, b)
    return params


def reduce_head(tparams: Pytree, keyword_classes=None) -> Pytree:
    """Collapse an n-class head to the student's 2 classes (paper §III,
    35 -> 2).

    ``keyword_classes`` are the teacher columns that mean-pool into
    student class 1 (the keyword); every other column pools into the
    background class 0.  Default: the odd classes — the fine-grained
    surrogate's coarsening rule (``data.pipeline.keyword_batch``: class c
    is a variant of binary class ``c % 2``).  Only the head changes; the
    encoder transfers as-is.
    """
    hw, hb = tparams["head_w"], tparams["head_b"]
    n = hw.shape[-1]
    if keyword_classes is None:
        keyword_classes = range(1, n, 2)
    kw_idx = jnp.asarray(sorted(set(int(c) for c in keyword_classes)))
    assert 0 < kw_idx.shape[0] < n, "keyword classes must be a proper subset"
    bg_idx = jnp.asarray([c for c in range(n)
                          if c not in set(kw_idx.tolist())])
    bg_w = jnp.mean(hw[:, bg_idx], axis=-1, keepdims=True)
    kw_w = jnp.mean(hw[:, kw_idx], axis=-1, keepdims=True)
    bg_b = jnp.mean(hb[bg_idx])[None]
    kw_b = jnp.mean(hb[kw_idx])[None]
    return {**tparams,
            "head_w": jnp.concatenate([bg_w, kw_w], axis=-1),
            "head_b": jnp.concatenate([bg_b, kw_b])}


def shrink_teacher(tparams: Pytree, tcfg, keep_layers: int,
                   batches, loss_fn=kwt.loss_fn):
    """Ablation-driven depth shrink (tools.surgeon): score each block by
    its ablation loss increase and keep only the ``keep_layers`` highest-
    impact blocks — the cheap KD teacher feeding the distill student."""
    from repro.tools import surgeon

    _, scores = surgeon.ablation_scores(tparams, tcfg, batches, loss_fn)
    shrunk = surgeon.shrink_params(tparams, scores, keep=keep_layers)
    return shrunk, tcfg.with_(n_layers=keep_layers)


@dataclasses.dataclass(frozen=True)
class DistillSpec:
    """KD configuration: a (reduced-head) float teacher + loss weights."""

    teacher_params: Any
    teacher_cfg: Any
    alpha: float = 0.5             # KD weight: (1-a)*CE + a*KD
    temperature: float = 2.0


def make_distill_loss(spec: DistillSpec):
    """A ``loss(params, batch, cfg)`` in the ``steps`` contract: CE on the
    hard labels + temperature-softened KL to the float teacher.  ``cfg``
    is the *student's* exec config (the QAT step passes the backend-pinned
    one), so the student side runs the deployed numerics while the
    teacher stays exact float."""
    t = float(spec.temperature)
    a = float(spec.alpha)

    def loss(params, batch, cfg):
        s_logits = kwt.forward(params, batch["mfcc"], cfg)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(s_logits, axis=-1)
        gold = jnp.take_along_axis(s_logits, labels[:, None], axis=-1)[:, 0]
        ce = jnp.mean(logz - gold)
        t_logits = jax.lax.stop_gradient(kwt.forward(
            spec.teacher_params, batch["mfcc"], spec.teacher_cfg))
        t_soft = jax.nn.log_softmax(t_logits / t, axis=-1)
        s_soft = jax.nn.log_softmax(s_logits / t, axis=-1)
        kd = jnp.mean(jnp.sum(jnp.exp(t_soft) * (t_soft - s_soft), axis=-1))
        return (1.0 - a) * ce + a * (t * t) * kd

    return loss
