"""repro.qat — quantisation-aware training for the deployed numerics.

Trains exactly the model the Engine deploys: the loss forward runs
eq-9 fake-quant weights (STE, ``qat.fakequant``) under a runtime
Backend's LUT execution modes, AdamW updates float shadow weights, and
``qat.export`` collapses the result into a ``QuantRecipe`` + int8 params
whose ``runtime.compile_model(..., backend="lut")`` logits are
BIT-IDENTICAL to the QAT eval path.  ``qat.distill`` adds KD from a
float KWT-1 teacher (paper §III's 35->2 retraining route).

    spec = qat.QATSpec(runtime.QuantRecipe.from_config(cfg))
    step = steps.make_train_step(cfg, shape, hp, qat=spec)
    qstate = qat.init_qat_state(spec)
    params, opt, qstate, metrics = step(params, opt, qstate, batch)
    ex = qat.export(params, spec, qstate)
    eng = runtime.compile_model(cfg, ex.params, backend="lut",
                                recipe=ex.recipe)
"""

from repro.qat.export import QATExport, eval_forward, export
from repro.qat.fakequant import (calibrate_exponent, fake_quant,
                                 fake_quant_input, fake_quant_tree)
from repro.qat.train import (QATConfig, QATSpec, finetune_qat,
                             init_qat_state, make_qat_train_step, qat_params)

__all__ = ["QATConfig", "QATExport", "QATSpec", "calibrate_exponent",
           "eval_forward", "export", "fake_quant", "fake_quant_input",
           "fake_quant_tree", "finetune_qat", "init_qat_state",
           "make_qat_train_step", "qat_params"]
