"""Quantisation-aware training: the deployed numerics inside the loss.

The QAT train step is ``launch.steps.make_train_step``'s quantised mode
(``steps.make_train_step(..., qat=QATSpec(...))`` delegates here): the
loss forward runs fake-quant params (``qat.fakequant``, STE) under the
execution config of a ``repro.runtime`` backend (default ``"lut"`` —
Q8.24 LUT softmax + LUT GELU, the '+Hardware' numerics), while the float
*shadow* weights are what ``optim.adamw`` updates.  State threads a small
``qstate`` pytree::

    step(params, opt_state, qstate, batch) -> (params, opt_state, qstate, metrics)
    # with sync_mesh (dist.compress):
    step(params, opt_state, qstate, err, batch) -> (..., qstate, err, metrics)

``qstate = {"step", "weight_exponent"}`` checkpoints/restores through
``checkpoint.manager`` like any other tree (tests/test_qat.py round-trips
it bit-exactly and resumes deterministically).

Knobs (QATConfig): delayed start (float warm-up steps before fake-quant
activates), exponent learning (per-step recalibration of the Table V
weight exponent from the live shadow weights) with a freeze step, optional
eq-9 input fake-quant, and optional distillation (``qat.distill``) from a
float teacher.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.qat import fakequant
from repro.runtime import backends
from repro.runtime.recipe import QuantRecipe

Pytree = Any


@dataclasses.dataclass(frozen=True)
class QATConfig:
    """How the quantised forward enters training.

    ``backend`` names the runtime Backend whose softmax/act modes the loss
    runs under (the deployed numerics; ``"lut"`` = Q8.24 pipeline).
    ``start_step`` delays weight fake-quant (float warm-up; LUT activation
    modes are structural in the compiled step and active throughout).
    ``learn_exponent`` recalibrates the weight exponent from the shadow
    weights every step until ``freeze_exponent_step`` (``0`` = never
    freeze), then freezes it — the learned value exports into the
    ``QuantRecipe`` (``qat.export``).  ``quantize_inputs`` applies the
    eq-9 input cast (Table V inputs 2^5) to float batch features during
    training only.
    """

    backend: str = "lut"
    start_step: int = 0
    learn_exponent: bool = False
    freeze_exponent_step: int = 0      # 0: recalibrate every step
    quantize_inputs: bool = False


@dataclasses.dataclass(frozen=True)
class QATSpec:
    """Everything ``steps.make_train_step(qat=...)`` needs: the recipe
    (quantiser semantics — ONE source of truth with PTQ/engine) and the
    training-side knobs."""

    recipe: QuantRecipe
    config: QATConfig = QATConfig()
    distill: Optional[Any] = None      # qat.distill.DistillSpec

    def exec_cfg(self, cfg):
        """The model config the QAT loss forward actually runs: the
        backend's approx modes pinned exactly as the Engine would."""
        return backends.get_backend(self.config.backend).configure(cfg)


def init_qat_state(spec: QATSpec) -> dict:
    return {"step": jnp.zeros((), jnp.int32),
            "weight_exponent": jnp.asarray(
                float(spec.recipe.weight_exponent), jnp.float32)}


def _fake_quant_batch(batch: dict, recipe: QuantRecipe) -> dict:
    """eq-9 cast on the float feature entries (mfcc/frames); int token
    ids and labels pass through."""
    def one(x):
        if isinstance(x, jnp.ndarray) and jnp.issubdtype(x.dtype,
                                                         jnp.floating):
            return fakequant.fake_quant_input(x, recipe)
        return x
    return {k: one(v) for k, v in batch.items()}


def _select_active(active, fq: Pytree, params: Pytree) -> Pytree:
    """Fake-quant values once QAT is active, raw shadow weights during
    the delayed-start warm-up (the ONE implementation of the gate — the
    train-step loss and the qat_params helper both use it)."""
    return jax.tree.map(
        lambda a, b: jnp.where(active, a, b.astype(a.dtype)), fq, params)


def qat_params(params: Pytree, spec: QATSpec, qstate: dict,
               exponent=None) -> Pytree:
    """The params the loss forward runs this step: fake-quant once active,
    raw float shadow weights during the delayed-start warm-up."""
    e = qstate["weight_exponent"] if exponent is None else exponent
    fq = fakequant.fake_quant_tree(params, spec.recipe, exponent=e)
    return _select_active(qstate["step"] >= spec.config.start_step,
                          fq, params)


def next_exponent(params: Pytree, spec: QATSpec, qstate: dict) -> jnp.ndarray:
    """This step's weight exponent: recalibrated from the live shadow
    weights while learning (until the freeze step; 0 = never freeze),
    or the recipe's static Table V value when learning is off."""
    e = qstate["weight_exponent"]
    if not spec.config.learn_exponent:
        return e
    e_new = fakequant.calibrate_exponent(params, spec.recipe)
    if spec.config.freeze_exponent_step <= 0:
        return e_new
    return jnp.where(qstate["step"] < spec.config.freeze_exponent_step,
                     e_new, e)


def make_qat_train_step(cfg, shape, hp=None, n_micro=None, sync_mesh=None,
                        sync_per_channel=False, sync_bits=8, *,
                        qat: QATSpec):
    """The QAT reading of ``steps.make_train_step`` (which delegates here).

    Per step: (1) resolve this step's weight exponent (learning /
    frozen), (2) fake-quant the shadow params (STE) and run the loss
    under the backend's approx modes — plain CE, or KD when
    ``qat.distill`` is set, (3) optionally compress-sync grads
    (``dist.compress``), (4) AdamW on the float shadow weights,
    (5) advance ``qstate``.
    """
    from repro.launch import steps  # late: steps imports us the same way

    hp = hp or steps.hparams_for(cfg)
    n_micro = n_micro or steps.microbatches(cfg, shape)
    exec_cfg = qat.exec_cfg(cfg)
    base_loss = steps._loss(cfg)
    if qat.distill is not None:
        from repro.qat import distill as distill_mod
        base_loss = distill_mod.make_distill_loss(qat.distill)

    def loss_at(params, batch, e, active):
        fq = fakequant.fake_quant_tree(params, qat.recipe, exponent=e)
        run = _select_active(active, fq, params)
        if qat.config.quantize_inputs:
            batch = _fake_quant_batch(batch, qat.recipe)
        return base_loss(run, batch, exec_cfg)

    def split_micro(batch):
        def f(x):
            b = x.shape[0]
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])
        return jax.tree.map(f, batch)

    def compute_grads(params, batch, e, active):
        if n_micro == 1:
            return jax.value_and_grad(loss_at)(params, batch, e, active)
        micro = split_micro(batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(loss_at)(params, mb, e, active)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / n_micro, acc, g)
            return acc, l

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, losses = jax.lax.scan(body, zeros, micro)
        return jnp.mean(losses), grads

    def finish(loss, grads, opt_state, params, qstate, e, active):
        new_params, new_opt, metrics = adamw.update(
            grads, opt_state, params, hp, scan_stacked=cfg.scan_layers)
        metrics.update(loss=loss, weight_exponent=e,
                       qat_active=active.astype(jnp.float32))
        new_q = {"step": qstate["step"] + 1, "weight_exponent": e}
        return new_params, new_opt, new_q, metrics

    if sync_mesh is None:
        def train_step(params, opt_state, qstate, batch):
            e = next_exponent(params, qat, qstate)
            active = qstate["step"] >= qat.config.start_step
            loss, grads = compute_grads(params, batch, e, active)
            return finish(loss, grads, opt_state, params, qstate, e, active)
        return train_step

    from repro.dist import compress

    def train_step_synced(params, opt_state, qstate, err, batch):
        e = next_exponent(params, qat, qstate)
        active = qstate["step"] >= qat.config.start_step
        loss, grads = compute_grads(params, batch, e, active)
        grads, err = compress.compressed_grad_sync(
            grads, err, sync_mesh, per_channel=sync_per_channel,
            bits=sync_bits)
        new_params, new_opt, new_q, metrics = finish(
            loss, grads, opt_state, params, qstate, e, active)
        return new_params, new_opt, new_q, err, metrics

    return train_step_synced


def finetune_qat(cfg, params, spec: QATSpec, n_steps: int, *, lr: float = 1e-3,
                 batch: int = 64, seed: int = 0, data_offset: int = 100_000,
                 fine_classes: int | None = None, select_fn=None,
                 select_every: int = 25):
    """Host-side KWT QAT fine-tune loop (the examples/benchmarks driver).

    Starts from float ``params`` (a trained baseline or a fresh init),
    runs ``n_steps`` of the QAT step on a fresh data fold, and returns
    ``(params, qstate)``.  ``fine_classes`` draws the GSC-35-style
    fine-grained surrogate batches coarsened to binary labels (the KD
    regime: the teacher stays on-distribution, the student sees the full
    variant spread).

    ``select_fn(deployed_params) -> score`` enables best-checkpoint
    selection on a *validation* fold: every ``select_every`` steps (plus
    step 0 and the final step) the candidate export is scored, and the
    best state wins.  Step 0's export IS the PTQ model, so a selected QAT
    run never ships worse than PTQ on the selection fold — report final
    accuracy on a disjoint test fold.
    """
    from repro.configs.base import ShapeSpec
    from repro.data import pipeline
    from repro.launch import steps

    assert cfg.family == "kwt", "finetune_qat drives the KWT surrogate task"
    shape = ShapeSpec("qat_ft", cfg.input_dim[1], batch, "train")
    hp = adamw.HParams(lr=lr, warmup_steps=max(2, n_steps // 10),
                       total_steps=max(n_steps, 10), weight_decay=0.0)
    step = jax.jit(steps.make_train_step(cfg, shape, hp, n_micro=1,
                                         qat=spec))
    opt = adamw.init(params, hp)
    qstate = init_qat_state(spec)
    best = None

    def consider(p, qs):
        nonlocal best
        if select_fn is None:
            return
        recipe = spec.recipe
        if spec.config.learn_exponent:
            recipe = recipe.with_(weight_exponent=int(qs["weight_exponent"]))
        score = float(select_fn(recipe.apply(p)))
        if best is None or score > best[0]:
            best = (score, p, qs)

    consider(params, qstate)
    for i in range(n_steps):
        b = pipeline.keyword_batch(
            seed, data_offset + i, batch=batch, input_dim=cfg.input_dim,
            n_classes=fine_classes or cfg.n_classes)
        if fine_classes:
            b = {"mfcc": b["mfcc"], "labels": b["labels"] % cfg.n_classes}
        params, opt, qstate, m = step(params, opt, qstate, b)
        # divergence guard on the selection cadence only — a per-step
        # host read of the loss would serialise batch generation against
        # device compute for the whole loop
        if (i + 1) % select_every == 0 and i != n_steps - 1:
            assert bool(jnp.isfinite(m["loss"])), "QAT loss diverged"
            consider(params, qstate)
    if n_steps > 0:
        assert bool(jnp.isfinite(m["loss"])), "QAT loss diverged"
    consider(params, qstate)
    if best is not None:
        return best[1], best[2]
    return params, qstate
