"""Q8.24 interval analysis: static overflow / precondition verification.

An abstract interpreter over jaxprs where every variable carries a value
interval ``[lo, hi]`` (exact Python ints for integer dtypes, floats for
float dtypes).  Constants — notably the LUT ROM tables from
``core/lut.py`` — enter with their concrete min/max, which is what makes
the analysis precise enough to verify the fixed-point pipelines: a gather
from ``LUT_EXP`` is *provably* in ``[e^-9.97, 1.0]`` in Q8.24 no matter
how wild the index interval is.

Checks performed while interpreting:

  * **int32 overflow**: every integer ``add``/``sub``/``mul``/
    ``reduce_sum``/``dot_general``/``shift_left`` whose exact mathematical
    result interval escapes the operand dtype's range.  A ``shift_left``
    (or any arithmetic op) whose result feeds ONLY ``select_n`` choice
    lanes is recognised as the repo's saturating-guard idiom
    (``jnp.where(a > limit, MAX, a << s)``) and reported as
    ``whitelisted`` instead — the wrapped value is statically dead.
  * **fixed_mul precondition**: the 12/12-limb product is exact only for
    24-bit magnitudes (``|a|,|b| <= 1.0`` in Q8.24).  The ``abs`` eqns
    inside ``fixed_mul`` are checked against ``ONE``; a violated bound is
    exactly the silent-wrap class the PR-5 review feared.

Verification is compositional (assume-guarantee): ``check_ranges`` runs
one contract per pipeline stage with declared input intervals (reported
as ``assumption`` findings), and the full-pipeline contract suppresses
checks inside stages that have their own dedicated contract.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import jaxpr_walk as jw
from repro.analysis.report import Finding, PassResult

_F32_MAX = 3.4028235e38


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: float
    hi: float

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    def hull(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def __repr__(self):
        return f"[{self.lo}, {self.hi}]"


def _is_int(dtype) -> bool:
    return jnp.issubdtype(dtype, jnp.integer) or dtype == jnp.bool_


def dtype_interval(dtype) -> Interval:
    if dtype == jnp.bool_:
        return Interval(0, 1)
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return Interval(int(info.min), int(info.max))
    return Interval(-_F32_MAX, _F32_MAX)


def from_value(val) -> Interval:
    arr = np.asarray(val)
    if arr.size == 0:
        return Interval(0, 0)
    if arr.dtype == np.bool_:
        return Interval(int(arr.min()), int(arr.max()))
    if np.issubdtype(arr.dtype, np.integer):
        return Interval(int(arr.min()), int(arr.max()))
    return Interval(float(arr.min()), float(arr.max()))


def _corners(f, a: Interval, b: Interval) -> Interval:
    vals = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            v = f(x, y)
            if isinstance(v, float) and math.isnan(v):
                return Interval(-math.inf, math.inf)
            vals.append(v)
    return Interval(min(vals), max(vals))


def _mono(f, a: Interval) -> Interval:
    lo, hi = f(a.lo), f(a.hi)
    return Interval(min(lo, hi), max(lo, hi))


def _shift_corners(f, a: Interval, s: Interval) -> Interval:
    slo = max(0, int(s.lo))
    shi = min(63, max(slo, int(s.hi)))
    vals = [f(int(x), y) for x in (a.lo, a.hi) for y in (slo, shi)]
    return Interval(min(vals), max(vals))


def _cmp(a: Interval, b: Interval, op: str) -> Interval:
    true_, false_ = Interval(1, 1), Interval(0, 0)
    if op in ("ge", "gt"):
        strict = op == "gt"
        if a.lo > b.hi or (not strict and a.lo >= b.hi):
            return true_
        if a.hi < b.lo or (strict and a.hi <= b.lo):
            return false_
    elif op in ("le", "lt"):
        strict = op == "lt"
        if a.hi < b.lo or (not strict and a.hi <= b.lo):
            return true_
        if a.lo > b.hi or (strict and a.lo >= b.hi):
            return false_
    elif op == "eq":
        if a.lo == a.hi == b.lo == b.hi:
            return true_
        if a.hi < b.lo or a.lo > b.hi:
            return false_
    elif op == "ne":
        if a.hi < b.lo or a.lo > b.hi:
            return true_
        if a.lo == a.hi == b.lo == b.hi:
            return false_
    return Interval(0, 1)


class _Ctx:
    """Shared per-analysis state: findings, options, dedup sets."""

    def __init__(self, findings, *, suppress_frames=(), check_fixed_mul=True,
                 label="", whitelist=()):
        self.findings = findings
        self.suppress_frames = frozenset(suppress_frames)
        self.check_fixed_mul = check_fixed_mul
        self.label = label
        self.whitelist = tuple(whitelist)   # (frame, primitive, reason)
        self._seen = set()
        self._suppressed_noted = set()
        self._cons_cache = {}

    def consumers(self, jaxpr):
        cons = self._cons_cache.get(id(jaxpr))
        if cons is None:
            cons = self._cons_cache[id(jaxpr)] = _consumer_map(jaxpr)
        return cons

    def once(self, key) -> bool:
        if key in self._seen:
            return False
        self._seen.add(key)
        return True

    def suppressed(self, eqn) -> bool:
        fns = jw.frame_functions(eqn)
        for f in fns:
            if f in self.suppress_frames:
                if f not in self._suppressed_noted:
                    self._suppressed_noted.add(f)
                    self.findings.append(Finding(
                        "info", "delegated",
                        f"{self.label}: checks inside {f!r} delegated to its "
                        "dedicated contract"))
                return True
        return False


def _consumer_map(jaxpr):
    """var id -> [(eqn, operand positions)] within one jaxpr level."""
    cons = {}
    for eqn in jaxpr.eqns:
        for i, v in enumerate(eqn.invars):
            if hasattr(v, "aval") and not hasattr(v, "val"):
                cons.setdefault(id(v), []).append((eqn, i))
    return cons


def _guarded_uses(var, jaxpr, ctx, depth=0) -> bool:
    """True when every (transitive) use of ``var`` is a ``select_n``
    choice lane (the saturating-guard idiom): the out-of-range value is
    statically dead — some predicate lane replaces it.  ``jnp.where``
    lowers to ``pjit[name=_where]``, so uses are followed through
    call-like primitives into the jaxpr where the select lives."""
    uses = ctx.consumers(jaxpr).get(id(var), [])
    if not uses or depth > 4:
        return False
    for user, pos in uses:
        if user.primitive.name == "select_n" and pos > 0:
            continue
        sub = user.params.get("jaxpr", user.params.get("call_jaxpr"))
        if sub is None:
            return False
        subj = jw.closed_to_open(sub)
        if len(subj.invars) != len(user.invars):
            return False
        if not _guarded_uses(subj.invars[pos], subj, ctx, depth + 1):
            return False
    return True


def _check_int_result(ctx, eqn, raw: Interval, jaxpr) -> Interval:
    """Flag integer results escaping their dtype; return the clamped
    interval (what saturation — or the guarding select — would keep)."""
    dtype = eqn.outvars[0].aval.dtype
    if not jnp.issubdtype(dtype, jnp.integer):
        return raw
    rng = dtype_interval(dtype)
    if raw.lo >= rng.lo and raw.hi <= rng.hi:
        return raw
    clamped = Interval(max(raw.lo, rng.lo), min(raw.hi, rng.hi))
    if not ctx.suppressed(eqn):
        site = jw.user_site(eqn)
        desc = (f"{ctx.label}: {eqn.primitive.name} on {dtype} may reach "
                f"{raw} (range {rng})")
        wl_reason = None
        fns = jw.frame_functions(eqn)
        for frame, prim, reason in ctx.whitelist:
            if prim == eqn.primitive.name and frame in fns:
                wl_reason = reason
                break
        if _guarded_uses(eqn.outvars[0], jaxpr, ctx):
            if ctx.once(("guard", eqn.primitive.name, site)):
                ctx.findings.append(Finding(
                    "whitelisted", "guarded-overflow",
                    desc + " — result only feeds saturating select lanes",
                    site))
        elif wl_reason is not None:
            if ctx.once(("wl", eqn.primitive.name, site)):
                ctx.findings.append(Finding(
                    "whitelisted", "known-safe-overflow",
                    desc + f" — {wl_reason}", site))
        elif ctx.once(("overflow", eqn.primitive.name, site)):
            ctx.findings.append(Finding(
                "violation", f"{dtype}-overflow",
                desc + " — unguarded: silently wraps", site))
    return clamped


def _precondition_check(ctx, eqn, operand: Interval):
    """The fixed_mul 24-bit-magnitude precondition, checked at its |.|."""
    one = 1 << 24
    if "fixed_mul" not in jw.frame_functions(eqn) or not ctx.check_fixed_mul:
        return
    if ctx.suppressed(eqn):
        return
    if operand.lo < -one or operand.hi > one:
        site = jw.user_site(eqn)
        if ctx.once(("precond", site)):
            ctx.findings.append(Finding(
                "violation", "fixed-mul-precondition",
                f"{ctx.label}: fixed_mul operand may reach {operand}; the "
                "12/12-limb product is only exact for |q| <= 2^24",
                site))


def _run(jaxpr, env, ctx):
    def read(v):
        if hasattr(v, "val"):                      # Literal
            return from_value(v.val)
        return env.get(id(v), dtype_interval(v.aval.dtype))

    def write(v, iv):
        env[id(v)] = iv

    for eqn in jaxpr.eqns:
        ins = [read(v) for v in eqn.invars]
        name = eqn.primitive.name
        out = None

        if name in ("add", "sub", "mul"):
            f = {"add": lambda x, y: x + y, "sub": lambda x, y: x - y,
                 "mul": lambda x, y: x * y}[name]
            out = _corners(f, ins[0], ins[1])
            out = _check_int_result(ctx, eqn, out, jaxpr)
        elif name == "div":
            a, b = ins
            if b.lo <= 0 <= b.hi:
                out = Interval(-math.inf, math.inf)
            else:
                out = _corners(lambda x, y: x / y, a, b)
        elif name == "neg":
            out = Interval(-ins[0].hi, -ins[0].lo)
        elif name == "abs":
            a = ins[0]
            out = Interval(0 if a.lo <= 0 <= a.hi else min(abs(a.lo), abs(a.hi)),
                           max(abs(a.lo), abs(a.hi)))
            _precondition_check(ctx, eqn, a)
        elif name == "sign":
            a = ins[0]
            out = Interval(-1 if a.lo < 0 else (0 if a.lo == 0 else 1),
                           1 if a.hi > 0 else (0 if a.hi == 0 else -1))
        elif name == "max":
            out = Interval(max(ins[0].lo, ins[1].lo), max(ins[0].hi, ins[1].hi))
        elif name == "min":
            out = Interval(min(ins[0].lo, ins[1].lo), min(ins[0].hi, ins[1].hi))
        elif name == "clamp":                       # lax.clamp(min, x, max)
            mn, x, mx = ins
            out = Interval(max(mn.lo, min(x.lo, mx.hi)),
                           max(mn.hi, min(x.hi, mx.hi)))
        elif name == "shift_left":
            out = _shift_corners(lambda a, s: a << s, ins[0], ins[1])
            out = _check_int_result(ctx, eqn, out, jaxpr)
        elif name in ("shift_right_arithmetic", "shift_right_logical"):
            a = ins[0]
            if name == "shift_right_logical" and a.lo < 0:
                out = dtype_interval(eqn.outvars[0].aval.dtype)
            else:
                out = _shift_corners(lambda x, s: x >> s, a, ins[1])
        elif name in ("and", "or", "xor"):
            dtype = eqn.outvars[0].aval.dtype
            if dtype == jnp.bool_:
                out = Interval(0, 1)
            elif all(i.lo >= 0 for i in ins):
                if name == "and":
                    out = Interval(0, min(i.hi for i in ins))
                else:
                    bits = max(int(i.hi).bit_length() for i in ins)
                    out = Interval(0, (1 << bits) - 1)
            else:
                out = dtype_interval(dtype)
        elif name == "not":
            out = (Interval(0, 1) if eqn.outvars[0].aval.dtype == jnp.bool_
                   else dtype_interval(eqn.outvars[0].aval.dtype))
        elif name in ("ge", "gt", "le", "lt", "eq", "ne"):
            out = _cmp(ins[0], ins[1], name)
        elif name == "select_n":
            pred, cases = ins[0], ins[1:]
            if pred.lo == pred.hi and 0 <= int(pred.lo) < len(cases):
                out = cases[int(pred.lo)]
            else:
                out = cases[0]
                for c in cases[1:]:
                    out = out.hull(c)
        elif name == "convert_element_type":
            dtype = eqn.outvars[0].aval.dtype
            a = ins[0]
            if _is_int(dtype):
                rng = dtype_interval(dtype)
                # XLA's float->int convert clamps at the type edges on the
                # backends we run; int->narrower-int wraps, so widen.
                lo = rng.lo if a.lo == -math.inf else int(math.floor(a.lo))
                hi = rng.hi if a.hi == math.inf else int(math.ceil(a.hi))
                if jnp.issubdtype(eqn.invars[0].aval.dtype, jnp.integer) \
                        and (lo < rng.lo or hi > rng.hi):
                    out = rng
                else:
                    out = Interval(max(lo, rng.lo), min(hi, rng.hi))
            else:
                out = Interval(float(a.lo), float(a.hi))
        elif name in ("reduce_max", "reduce_min", "reduce_and", "reduce_or",
                      "cumsum", "cummax"):
            out = ins[0]
            if name == "cumsum":
                n = int(eqn.invars[0].aval.size)
                out = _corners(lambda x, y: x * y, ins[0], Interval(1, n))
                out = _check_int_result(ctx, eqn, out, jaxpr)
        elif name == "reduce_sum":
            n = max(1, int(eqn.invars[0].aval.size) //
                    max(1, int(eqn.outvars[0].aval.size)))
            a = ins[0]
            out = Interval(min(a.lo * n, a.lo), max(a.hi * n, a.hi))
            out = _check_int_result(ctx, eqn, out, jaxpr)
        elif name == "dot_general":
            ((lc, _), _) = eqn.params["dimension_numbers"]
            k = 1
            for ax in lc:
                k *= int(eqn.invars[0].aval.shape[ax])
            prod = _corners(lambda x, y: x * y, ins[0], ins[1])
            out = Interval(min(prod.lo * k, prod.lo), max(prod.hi * k, prod.hi))
            out = _check_int_result(ctx, eqn, out, jaxpr)
        elif name in ("gather", "dynamic_slice", "slice", "rev", "copy",
                      "broadcast_in_dim", "reshape", "transpose", "squeeze",
                      "expand_dims", "device_put", "stop_gradient",
                      "reduce_precision"):
            out = ins[0]
        elif name == "concatenate":
            out = ins[0]
            for i in ins[1:]:
                out = out.hull(i)
        elif name == "pad":
            out = ins[0].hull(ins[1])
        elif name == "iota":
            d = int(eqn.params.get("dimension", 0))
            size = int(eqn.outvars[0].aval.shape[d]) if \
                eqn.outvars[0].aval.shape else 1
            out = Interval(0, max(0, size - 1))
        elif name == "optimization_barrier":
            for v, iv in zip(eqn.outvars, ins):
                write(v, iv)
            continue
        elif name in ("floor", "ceil", "round"):
            out = Interval(math.floor(ins[0].lo), math.ceil(ins[0].hi))
        elif name in ("exp", "exp2", "log", "log2", "tanh", "logistic",
                      "rsqrt", "sqrt", "erf", "sin", "cos", "integer_pow",
                      "pow", "is_finite"):
            out = _elementwise_math(name, eqn, ins)
        elif name in ("pjit", "closed_call", "custom_vjp_call_jaxpr",
                      "custom_jvp_call", "custom_vjp_call", "remat",
                      "checkpoint", "core_call"):
            sub = None
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params:
                    sub = eqn.params[key]
                    break
            if sub is None:
                subs = list(jw.sub_jaxprs(eqn))
                sub = subs[0] if subs else None
            outs = _run_sub(sub, ins, eqn, ctx) if sub is not None else None
            if outs is not None:
                for v, iv in zip(eqn.outvars, outs):
                    write(v, iv)
                continue
        # fall through: unknown / unhandled primitive
        if out is None:
            if ctx.once(("widen", name)):
                ctx.findings.append(Finding(
                    "info", "widened",
                    f"{ctx.label}: no transfer function for primitive "
                    f"{name!r}; result widened to its dtype range"))
            for v in eqn.outvars:
                write(v, dtype_interval(v.aval.dtype))
            continue
        write(eqn.outvars[0], out)
        for v in eqn.outvars[1:]:
            write(v, dtype_interval(v.aval.dtype))

    return [read(v) for v in jaxpr.outvars]


def _elementwise_math(name, eqn, ins):
    a = ins[0]
    fns = {
        "exp": lambda x: math.exp(min(x, 700.0)),
        "exp2": lambda x: 2.0 ** min(x, 1000.0),
        "log": lambda x: math.log(x) if x > 0 else -math.inf,
        "log2": lambda x: math.log2(x) if x > 0 else -math.inf,
        "tanh": math.tanh,
        "logistic": lambda x: 1.0 / (1.0 + math.exp(-max(min(x, 700), -700))),
        "erf": math.erf,
        "sqrt": lambda x: math.sqrt(max(x, 0.0)),
        "rsqrt": lambda x: (1.0 / math.sqrt(x)) if x > 0 else math.inf,
        "is_finite": None, "sin": None, "cos": None,
        "integer_pow": None, "pow": None,
    }
    if name in ("sin", "cos"):
        return Interval(-1.0, 1.0)
    if name == "is_finite":
        return Interval(0, 1)
    if name == "integer_pow":
        y = int(eqn.params["y"])
        vals = [x ** y for x in (a.lo, a.hi)]
        if y % 2 == 0 and a.lo <= 0 <= a.hi:
            vals.append(0)
        return Interval(min(vals), max(vals))
    if name == "pow":
        return _corners(lambda x, y: x ** y if x > 0 else 0.0, a, ins[1])
    return _mono(fns[name], a)


def _run_sub(sub, ins, eqn, ctx):
    """Interpret a nested (Closed)Jaxpr, mapping operand intervals in."""
    consts = list(getattr(sub, "consts", ()) or ())
    jaxpr = jw.closed_to_open(sub)
    env = {}
    for v, c in zip(jaxpr.constvars, consts):
        env[id(v)] = from_value(c)
    if len(jaxpr.invars) == len(ins):
        mapped = ins
    else:
        # operand packing we don't model (scan carries etc.): widen.
        mapped = [dtype_interval(v.aval.dtype) for v in jaxpr.invars]
    for v, iv in zip(jaxpr.invars, mapped):
        env[id(v)] = iv
    outs = _run(jaxpr, env, ctx)
    if len(outs) != len(eqn.outvars):
        return None
    return outs


def analyze_fn(fn, example_args, input_intervals, *, label="fn",
               suppress_frames=(), check_fixed_mul=True, whitelist=()):
    """Interval-analyze ``fn`` traced at ``example_args``.

    ``input_intervals``: one Interval per flattened input leaf (None
    entries default to the leaf dtype's full range).  Returns
    ``(findings, out_intervals)``.
    """
    findings = []
    ctx = _Ctx(findings, suppress_frames=suppress_frames,
               check_fixed_mul=check_fixed_mul, label=label,
               whitelist=whitelist)
    closed = jax.make_jaxpr(fn)(*example_args)
    jaxpr = closed.jaxpr
    env = {}
    for v, c in zip(jaxpr.constvars, closed.consts):
        env[id(v)] = from_value(c)
    leaves = jax.tree.leaves(example_args)
    ivs = list(input_intervals) + [None] * (len(leaves) - len(input_intervals))
    for v, leaf, iv in zip(jaxpr.invars, leaves, ivs):
        env[id(v)] = iv if iv is not None else dtype_interval(v.aval.dtype)
    outs = _run(jaxpr, env, ctx)
    return findings, outs


# ---------------------------------------------------------------------------
# Engine-level contracts
# ---------------------------------------------------------------------------

def _assume(findings, label, text):
    findings.append(Finding("assumption", "domain-fact", f"{label}: {text}"))


def check_ranges(engine, x) -> PassResult:
    """Run the Q8.24 contracts selected by the engine's execution modes."""
    from repro.core import approx, fixedpoint as fxp, lut as lutlib

    cfg = engine.exec_cfg
    findings = []
    metrics = {}
    one = 1 << fxp.FRAC_BITS
    fixed_modes = ("lut_fixed", "pallas")
    if cfg.softmax_mode not in fixed_modes and cfg.act_approx == "exact":
        findings.append(Finding(
            "info", "scope", "plan uses no fixed-point pipelines; nothing "
            "to range-check"))
        return PassResult("ranges", findings, metrics)
    if cfg.softmax_mode == "pallas":
        findings.append(Finding(
            "info", "scope",
            "pallas kernels execute the same Q8.24 ops tile-by-tile; "
            "contracts verify the jnp reference pipeline the kernels are "
            "bit-checked against (tests/test_kernels.py)"))

    if cfg.family == "kwt":
        from repro.models import kwt
        k_lens = [kwt.seqlen(cfg)]
    else:
        k_lens = [int(x.shape[-1])] if hasattr(x, "shape") and x.ndim else [64]

    if cfg.softmax_mode in fixed_modes:
        for k in k_lens:
            pre = max(0, int(np.ceil(np.log2(max(k, 1)))) - 6)
            label = f"softmax_q824[K={k}]"
            # (1) full pipeline; reciprocal + product have own contracts
            f1, _ = analyze_fn(
                lambda v: approx.softmax(v, mode="lut_fixed"),
                (jnp.zeros((1, k)),), [None], label=label,
                suppress_frames=("reciprocal_q24", "fixed_mul"))
            findings += f1
            # (2) reciprocal stage under the dominant-lane row-sum bound
            _assume(findings, label,
                    f"row sum s_q >= 2^(24-pre)={1 << (24 - pre)} (the "
                    "max-normalised row always has a z=0 lane at e^0=1)")
            bank = lutlib.make_lut_bank()
            f2, _ = analyze_fn(
                lambda s: lutlib.reciprocal_q24(s, bank),
                (jnp.zeros((1, 1), jnp.int32),),
                [Interval(one >> pre, k * (one >> pre))],
                label=f"{label}/reciprocal",
                whitelist=((
                    "reciprocal_q24", "shift_left",
                    "mantissa normalisation (s>>tp)<<tn: tp/tn are "
                    "magnitude-correlated with s (ilog2), so the result "
                    "is in [1,2) Q8.24 — invisible to intervals"),))
            findings += f2
            # (3) the normalisation product's exactness precondition
            _assume(findings, label,
                    "1/s <= 2^pre in Q8.24 (s >= 2^-pre real), so the "
                    "post-shift reciprocal magnitude is <= 1.0")
            f3, _ = analyze_fn(
                fxp.fixed_mul,
                (jnp.zeros((1, k), jnp.int32), jnp.zeros((1, 1), jnp.int32)),
                [Interval(0, one), Interval(0, one)],
                label=f"{label}/normalise")
            findings += f3
        metrics["softmax_contracts"] = 3 * len(k_lens)

    if cfg.act_approx in ("lut", "pallas") and cfg.activation == "gelu":
        f4, _ = analyze_fn(
            lambda v: approx.gelu(v, mode="lut"),
            (jnp.zeros((1, max(k_lens))),), [None], label="gelu_lut")
        findings += f4
        metrics["gelu_contracts"] = 1

    # (4) the power-of-2 rescale primitive at the recipe's input gain —
    # the exact site the PR-6 satellite fix saturates.
    shift = engine.recipe.input_exponent if engine.recipe else 5
    envelope = 8.0
    _assume(findings, "po2_rescale",
            f"normalised activations |x| <= {envelope} entering the input "
            f"gain 2^{shift} (post-LayerNorm envelope)")
    f5, _ = analyze_fn(
        lambda v: fxp.fixed_shift_mul(fxp.to_fixed(v), shift),
        (jnp.zeros((4,)),), [Interval(-envelope, envelope)],
        label="po2_rescale")
    findings += f5
    metrics["violations"] = sum(
        1 for f in findings if f.severity == "violation")
    return PassResult("ranges", findings, metrics)
