"""Shared jaxpr-walking utilities for the static-analysis passes.

``jax.make_jaxpr`` gives the pass pipeline one canonical view of a jitted
program: a list of equations over typed variables, with call-like
primitives (``pjit``, ``custom_vjp_call_jaxpr``, ``scan``, ``cond``,
``pallas_call``, ...) carrying nested jaxprs in their params.  The three
helpers here are the only places that touch jax internals:

  ``sub_jaxprs(eqn)``   - every nested Jaxpr inside an equation's params;
  ``iter_eqns(jaxpr)``  - depth-first traversal over all equations;
  ``user_site(eqn)``    - the repo-level (function, file, line) frames an
                          equation was traced from, for whitelists and
                          human-readable reports.
"""

from __future__ import annotations

from jax._src import source_info_util  # noqa: PLC2701  (no public API yet)


def closed_to_open(j):
    """Return the open Jaxpr of a (possibly Closed) jaxpr object."""
    inner = getattr(j, "jaxpr", None)
    return inner if inner is not None and hasattr(inner, "eqns") else j


def sub_jaxprs(eqn):
    """Yield every nested (open) Jaxpr referenced by an equation's params."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if hasattr(v, "jaxpr") or (hasattr(v, "eqns") and
                                       hasattr(v, "invars")):
                yield closed_to_open(v)


def iter_eqns(jaxpr, depth: int = 0):
    """Depth-first (eqn, depth) traversal, recursing into nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn, depth
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, depth + 1)


def user_frames(eqn):
    """Repo-level stack frames (innermost first) for an equation."""
    try:
        return list(source_info_util.user_frames(eqn.source_info))
    except Exception:
        return []


def frame_functions(eqn) -> list:
    """Function names of the user frames (innermost first)."""
    return [f.function_name for f in user_frames(eqn)]


def user_site(eqn) -> str:
    """Human-readable innermost repo frame: ``fn (file.py:line)``."""
    frames = user_frames(eqn)
    if not frames:
        return ""
    f = frames[0]
    fname = f.file_name.rsplit("/", 1)[-1]
    return f"{f.function_name} ({fname}:{f.start_line})"


def aval_bytes(aval) -> int:
    """Buffer bytes of an abstract value (bools count one byte)."""
    try:
        return int(aval.size) * max(int(aval.dtype.itemsize), 1)
    except Exception:
        return 0
