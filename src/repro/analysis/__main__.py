"""CLI: python -m repro.analysis check --config kwt_tiny --backend lut

Runs the static-analysis pass pipeline over one compiled Engine plan and
exits nonzero when any pass reports a violation — the CI analysis-gate
entry point.  ``--mutate`` seeds a known violation (mutation testing:
the gate asserts the checker FAILS on each one).
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro import analysis
from repro.analysis import mutations


def _build_engine(config: str, backend: str, seed: int):
    from repro import runtime
    from repro.configs import registry

    cfg = registry.get(config.replace("_", "-")).config
    if cfg.family != "kwt":
        raise SystemExit(
            f"config {cfg.name!r}: the analysis CLI builds params for the "
            "kwt family; analyse other families via analysis.check_engine")
    from repro.models import kwt
    params = kwt.init_params(cfg, jax.random.PRNGKey(seed))
    return runtime.compile_model(cfg, params, backend=backend)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser("check", help="run the pass pipeline on one plan")
    chk.add_argument("--config", default="kwt_tiny",
                     help="registry config name (kwt_tiny / kwt_1 / ...)")
    chk.add_argument("--backend", default="lut",
                     help="runtime backend (float / lut_float / lut / pallas)")
    chk.add_argument("--passes", default=",".join(analysis.PASSES),
                     help="comma-separated subset of "
                          f"{','.join(analysis.PASSES)}")
    chk.add_argument("--budget", type=int, default=None,
                     help="override the RAM gate in bytes (default: 64 kB "
                          "for the paper's deployment config)")
    chk.add_argument("--mutate", default="none",
                     choices=("none",) + mutations.MUTATIONS,
                     help="seed a known violation (checker self-test)")
    chk.add_argument("--strict", action="store_true",
                     help="full-integer gate: residency pass demands an "
                          "integer-executing plan with float_leak_count==0 "
                          "and no whole-tensor float weight views")
    chk.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    with mutations.apply(args.mutate):
        engine = _build_engine(args.config, args.backend, args.seed)
        report = analysis.check_engine(
            engine, passes=tuple(args.passes.split(",")),
            budget=args.budget, strict=args.strict)
    print(report.render())
    if args.mutate != "none":
        print(f"[mutation {args.mutate!r} seeded: "
              f"{'CAUGHT' if not report.ok else 'MISSED'}]")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
