"""RAM-budget checker: does the plan fit the paper's 64 kB target?

The paper deploys KWT-Tiny on a bare-metal RISC-V board with 64 kB of
RAM; the whole point of int8 ROM + 2.69 kB LUT bank + Q8.24 activations
is staying inside it.  This pass computes the static footprint of an
Engine plan:

    total = deployed parameter bytes   (packed ints + residual floats)
          + LUT bank ROM bytes
          + peak activation live-set   (buffer liveness over the jaxpr)

The live-set walks the forward program's equations in order, allocating
each output buffer at its defining equation and freeing each operand
after its last use — the high-water mark is what a bump allocator (or
the board's static arena) must provision.  Weight leaves are excluded
from the live-set (already counted as parameter bytes); the input buffer
counts.

The 64 kB gate applies to the paper's deployment target (the kwt-tiny
config); other configs get the same table as information — kwt_1 at
~607k params is a desktop model and is *reported* against the budget,
not failed.
"""

from __future__ import annotations

import jax

from repro.analysis import jaxpr_walk as jw
from repro.analysis.report import Finding, PassResult

PAPER_BUDGET_BYTES = 64 * 1024

# Config names gated (not just reported) against the paper budget.
_GATED_CONFIGS = ("kwt-tiny",)


def _peak_live(jaxpr, count_invar, ctx_bytes=0):
    """High-water-mark live bytes over one jaxpr's equations.

    ``count_invar``: per-invar flags — weight operands are excluded (the
    caller counts them as parameter ROM).  Nested jaxprs (pjit bodies,
    custom_vjp primals) are charged against the live set at their call
    site; their invars alias already-counted outer buffers, so only
    their internal temporaries add bytes.
    """
    last = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if hasattr(v, "aval") and not hasattr(v, "val"):
                last[id(v)] = i
    for v in jaxpr.outvars:
        if hasattr(v, "aval") and not hasattr(v, "val"):
            last[id(v)] = len(jaxpr.eqns)

    live = {}
    for v, counted in zip(jaxpr.invars, count_invar):
        if counted and id(v) in last:
            live[id(v)] = jw.aval_bytes(v.aval)
    for v in jaxpr.constvars:
        if id(v) in last:
            live[id(v)] = jw.aval_bytes(v.aval)

    peak = sum(live.values()) + ctx_bytes
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            if id(v) in last:
                live[id(v)] = jw.aval_bytes(v.aval)
        cur = sum(live.values()) + ctx_bytes
        for sub in jw.sub_jaxprs(eqn):
            peak = max(peak, _peak_live(
                sub, [False] * len(sub.invars), cur))
        peak = max(peak, cur)
        for v in eqn.invars:
            if id(v) in last and last[id(v)] == i:
                live.pop(id(v), None)
    return peak


def peak_activation_bytes(fn, params, x) -> int:
    """Peak live activation bytes of ``fn(params, x)`` traced at ``x``."""
    closed = jax.make_jaxpr(fn)(params, x)
    n_param = len(jax.tree.leaves(params))
    n_in = len(closed.jaxpr.invars)
    flags = [False] * n_param + [True] * (n_in - n_param)
    return _peak_live(closed.jaxpr, flags)


def check_budget(engine, x, budget: int | None = None) -> PassResult:
    """Static RAM table for the plan; gated for the paper's target config."""
    findings = []
    cfg = engine.exec_cfg
    gated = budget is not None or cfg.name in _GATED_CONFIGS
    cap = PAPER_BUDGET_BYTES if budget is None else budget
    if gated and budget is None and engine.backend.uses_kernels:
        # Pallas plans stage pad_to_block tile buffers + whole-table VMEM
        # operands — TPU working memory, not board RAM.  The 64 kB gate
        # models the bare-metal C deployment, which maps to the kernel-
        # free (lut) plan; kernel plans get the table informationally.
        gated = False
        findings.append(Finding(
            "info", "ram-budget-scope",
            f"backend {engine.backend_name!r} stages Pallas tile buffers "
            "(TPU VMEM, not board RAM); the 64 kB gate is enforced on the "
            "kernel-free deployment plan — table reported informationally"))

    act = peak_activation_bytes(
        lambda p, xx: engine._mod.forward(p, xx, cfg), engine.params, x)
    rom = engine.rom_bytes
    lut = engine.lut_bytes
    residual = engine.param_bytes - rom
    total = engine.param_bytes + lut + act

    metrics = {
        "rom_bytes": rom, "lut_bytes": lut,
        "residual_float_bytes": residual,
        "peak_activation_bytes": act,
        "total_bytes": total,
        "budget_bytes": cap if gated else 0,
    }
    shape = list(getattr(x, "shape", ()))
    findings.append(Finding(
        "info", "ram-table",
        f"{cfg.name}/{engine.backend_name} @ input {shape}: "
        f"rom {rom} B + residual {residual} B + lut {lut} B + "
        f"activations {act} B = {total} B"))
    if gated:
        if total > cap:
            findings.append(Finding(
                "violation", "ram-budget",
                f"{total} B exceeds the {cap} B deployment budget "
                f"(over by {total - cap} B)"))
        else:
            findings.append(Finding(
                "info", "ram-budget",
                f"fits the {cap} B target with {cap - total} B headroom"))
    else:
        findings.append(Finding(
            "info", "ram-budget",
            f"{PAPER_BUDGET_BYTES} B gate not enforced for this plan; "
            f"informationally it {'is OVER' if total > PAPER_BUDGET_BYTES else 'fits'}"))
    return PassResult("budget", findings, metrics)
