"""Pallas geometry pass: validate kernel block shapes before launch.

Every ``pallas_call`` in the plan's forward program is checked statically
— at plan time, not when the kernel first faults on device:

  * **VMEM fit**: the per-step working set (block bytes over all operand
    and output BlockSpecs, doubled for the pipeline's double-buffering)
    must fit in a core's ~16 MB of VMEM.
  * **Mosaic tiling**: compiled (non-interpret) plans want the last axis
    a multiple of 128 lanes and the second-to-last a multiple of 8
    sublanes (float32 tiling); interpret-mode plans get the same note as
    a warning, since flipping ``kernel_interpret`` is how these plans
    reach real hardware.
  * **Grid consistency**: a zero/negative grid axis or a block larger
    than its (padded) array means ``pad_to_block``/``fit_block`` chose
    an impossible geometry.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_walk as jw
from repro.analysis.report import Finding, PassResult

VMEM_BYTES = 16 * 1024 * 1024      # per-core VMEM (pallas guide)
_LANE, _SUBLANE = 128, 8           # float32 Mosaic tile


def _block_bytes(bm) -> int:
    shape = [int(d) for d in bm.block_shape if d is not None]
    dtype = bm.array_shape_dtype.dtype
    return int(math.prod(shape)) * int(jnp.dtype(dtype).itemsize)


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    name = getattr(info, "name", None) or str(info or "pallas_call")
    return name.split(" ")[0]


def check_geometry(engine, x) -> PassResult:
    """Walk the forward jaxpr and vet every pallas_call's geometry."""
    findings = []
    metrics = {"kernels": 0, "max_vmem_bytes": 0}
    cfg = engine.exec_cfg
    closed = jax.make_jaxpr(
        lambda p, xx: engine._mod.forward(p, xx, cfg))(engine.params, x)

    for eqn, _ in jw.iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        metrics["kernels"] += 1
        name = _kernel_name(eqn)
        gm = eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in gm.grid)
        interpret = bool(eqn.params.get("interpret", False))

        if any(g <= 0 for g in grid):
            findings.append(Finding(
                "violation", "empty-grid",
                f"{name}: grid {grid} has a non-positive axis",
                jw.user_site(eqn)))
            continue

        vmem = 0
        for bm in gm.block_mappings:
            vmem += _block_bytes(bm)
            block = tuple(int(d) for d in bm.block_shape if d is not None)
            arr = tuple(int(d) for d in bm.array_shape_dtype.shape)
            if len(block) == len(arr) and any(
                    b > max(a, 1) and b % max(a, 1) != 0
                    for b, a in zip(block, arr)):
                findings.append(Finding(
                    "violation", "block-overhang",
                    f"{name}: block {block} is not a tile of array "
                    f"{arr} (pad_to_block/fit_block mismatch)",
                    jw.user_site(eqn)))
            if len(block) >= 1 and block[-1] % _LANE != 0 or \
                    len(block) >= 2 and block[-2] % _SUBLANE != 0:
                findings.append(Finding(
                    "warning", "mosaic-tile",
                    f"{name}: block {block} is not {_SUBLANE}x{_LANE}-"
                    "aligned — fine in interpret mode"
                    + ("" if interpret else
                       "; Mosaic will pad or reject it"),
                    jw.user_site(eqn)))

        working = 2 * vmem            # double-buffered pipeline
        metrics["max_vmem_bytes"] = max(metrics["max_vmem_bytes"], working)
        if working > VMEM_BYTES:
            findings.append(Finding(
                "violation", "vmem-overflow",
                f"{name}: per-step working set {working} B "
                f"(2x double-buffer) exceeds VMEM {VMEM_BYTES} B; "
                "shrink the block via fit_block", jw.user_site(eqn)))
        else:
            findings.append(Finding(
                "info", "kernel-geometry",
                f"{name}: grid {grid}, working set {working} B "
                f"of {VMEM_BYTES} B VMEM "
                f"({'interpret' if interpret else 'mosaic'})"))

    if metrics["kernels"] == 0:
        findings.append(Finding(
            "info", "scope",
            f"plan {engine.backend_name!r} launches no Pallas kernels"))
    return PassResult("geometry", findings, metrics)
