"""Seeded violations that prove the checker checks (mutation testing).

Each context manager monkeypatches one invariant the pass pipeline
guards, so tests/CI can assert the corresponding pass flips to FAIL —
without the mutations, a regression in the checker itself (e.g. a taint
walk that silently stops recursing) would keep reporting green forever.

    float_leak   - residency: dequantise integer weights through a path
                   with no sanctioned frame (bypasses resident_values)
    unsat_shift  - ranges: restore the wrapping (pre-PR-6) left shift in
                   fixed_shift_mul
    big_lut      - budget: inflate the reported LUT bank past 64 kB

Usage::

    with mutations.apply("float_leak"):
        report = analysis.check_engine(engine)
    assert not report.ok
"""

from __future__ import annotations

import contextlib

import jax.numpy as jnp

MUTATIONS = ("float_leak", "unsat_shift", "big_lut")


@contextlib.contextmanager
def float_leak():
    """Dequantise stored-integer weights inline, with no sanctioned frame:
    the residency pass must flag the tainted int->float cast."""
    from repro.core import quant

    orig = quant.resident_values

    def _leaky_values(w):
        scale = jnp.float32(2.0 ** (-w.exponent))
        out = w.int_values().astype(jnp.float32) * scale
        if w.axis_exponents is not None:
            out = out * jnp.exp2(-w.axis_exponents.astype(jnp.float32))
        return out

    quant.resident_values = _leaky_values
    try:
        yield
    finally:
        quant.resident_values = orig


@contextlib.contextmanager
def unsat_shift():
    """Restore the wrapping left shift (the bug the PR-6 satellite fixed):
    the range pass must flag the unguarded int32 overflow."""
    from repro.core import fixedpoint as fxp

    orig = fxp.fixed_shift_mul

    def _wrapping(a, shift):
        if shift >= 0:
            return (a.astype(jnp.int32) << shift).astype(jnp.int32)
        return (a.astype(jnp.int32) >> (-shift)).astype(jnp.int32)

    fxp.fixed_shift_mul = _wrapping
    try:
        yield
    finally:
        fxp.fixed_shift_mul = orig


@contextlib.contextmanager
def big_lut():
    """Report a 70 kB LUT bank: the budget pass must fail the 64 kB gate."""
    from repro.runtime.engine import Engine

    orig = Engine.lut_bytes
    Engine.lut_bytes = property(lambda self: 70_000)
    try:
        yield
    finally:
        Engine.lut_bytes = orig


@contextlib.contextmanager
def apply(name: str | None):
    """Apply one mutation by name (None / "none": no-op)."""
    if name in (None, "none"):
        yield
        return
    if name not in MUTATIONS:
        raise ValueError(f"unknown mutation {name!r}; pick from {MUTATIONS}")
    with {"float_leak": float_leak, "unsat_shift": unsat_shift,
          "big_lut": big_lut}[name]():
        yield
