"""Dtype-residency lint: prove (or refute) ``Backend.int_resident``.

The Engine claims its lut/pallas plans keep quantised weights in stored
integer form.  This pass checks the claim at the jaxpr level instead of
by example: it walks the traced programs, propagates a taint set from the
integer weight-storage inputs (the packed QTensor leaves), and reports
every ``convert_element_type`` to float that is reachable from them.

Two programs are analysed per integer-resident plan:

  * the **unpack stage** (``Engine.live_params``'s jitted
    ``quant.dequantize_tree``) — the separate executable a
    non-executing resident Engine runs per call.  Every int->float cast
    here is the PR-5 "hidden unpack" leak: the weights are
    integer-*resident* but the model still consumes a float view.
    These are whitelisted with a report line and counted as
    ``float_leak_count``.  Integer-EXECUTING plans (``engine.int_exec``)
    have no unpack stage at all, so the count is zero by construction —
    the ROADMAP "full-integer execution" criterion.

  * the **in-module resident program** (the model forward traced directly
    on the packed tree — the path integer-executing plans and fused-jit
    drivers take).  Sanctioned casts are classified by their trace-time
    call stack:

      - frames through ``quant.resident_values`` — the po2 weight
        de-scale epilogue (exact, fusion-isolated); whitelisted.
      - frames through ``quant.int_container`` — value-preserving
        int->f32 container move for exact integer GEMM (the f32
        mantissa holds the int8 grid exactly); whitelisted.
      - frames through ``quant.requant`` / ``kernels.ops.int8_matmul``
        — the per-channel po2 requant epilogue on an integer
        accumulator; whitelisted.
      - frames through ``quant.gather_descale`` — row-wise embedding
        descale (only looked-up rows leave integer form); whitelisted.
      - frames through ``fixedpoint.to_float`` — the Q8.24 pipeline's
        exit boundary (the jnp reference's emulation of the device's
        ALU_TO_FLOAT instruction); whitelisted.

    Anything else tainted that converts an integer to a float is a
    **violation**: an unsanctioned dequantisation snuck into the plan.

**Strict mode** (``check_residency(..., strict=True)``, CLI
``python -m repro.analysis --strict``) asserts the FULL-integer claim:
the plan must be integer-executing, ``float_leak_count`` must be zero,
and whole-tensor weight descales feeding float einsums
(``quant.qt_einsum``'s float view) are violations even though plain
resident mode sanctions them — the only sanctioned float views left are
the additive-consumption leaves (positional tables) and the requant /
container / gather epilogues above.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_walk as jw
from repro.analysis.report import Finding, PassResult

# Trace-time frame names that sanction an int->float cast (innermost-wins
# classification below reports which rule fired).
_WHITELIST = (
    ("resident_values", "weight-descale",
     "po2 de-scale epilogue (exact, fusion-isolated)"),
    ("int_container", "int-container",
     "value-preserving int->f32 container move (exact integer GEMM)"),
    ("int8_matmul", "requant-epilogue",
     "per-channel po2 requant of the kernel's integer accumulator"),
    ("requant", "requant-epilogue",
     "per-channel po2 requant of the integer accumulator"),
    ("gather_descale", "gather-descale",
     "row-wise embedding descale (looked-up rows only)"),
    ("to_float", "q824-boundary",
     "Q8.24 pipeline exit (ALU_TO_FLOAT reference)"),
)


def _is_int(aval) -> bool:
    return jnp.issubdtype(aval.dtype, jnp.integer)


def _is_float(aval) -> bool:
    return jnp.issubdtype(aval.dtype, jnp.floating)


def _var_key(v):
    return id(v)


def _tainted_float_casts(jaxpr, taint_in, hits, depth=0):
    """Walk ``jaxpr`` propagating taint; append (eqn, in_aval) for every
    int->float convert_element_type whose operand is tainted."""
    tainted = set()
    for v, t in zip(jaxpr.invars, taint_in):
        if t:
            tainted.add(_var_key(v))

    for eqn in jaxpr.eqns:
        in_taint = [(_var_key(v) in tainted) if hasattr(v, "aval") and
                    not isinstance(v, jax.core.Literal) else False
                    for v in eqn.invars]
        any_taint = any(in_taint)
        if (eqn.primitive.name == "convert_element_type" and in_taint[0]
                and _is_int(eqn.invars[0].aval)
                and _is_float(eqn.outvars[0].aval)):
            hits.append(eqn)
        for sub in jw.sub_jaxprs(eqn):
            if len(sub.invars) == len(eqn.invars):
                sub_taint = in_taint
            else:
                # scan/cond-style operand packing: conservative — taint
                # every inner input if any outer operand is tainted.
                sub_taint = [any_taint] * len(sub.invars)
            _tainted_float_casts(sub, sub_taint, hits, depth + 1)
        if any_taint:
            for v in eqn.outvars:
                tainted.add(_var_key(v))


def _classify(eqn):
    fns = jw.frame_functions(eqn)
    for fn, kind, why in _WHITELIST:
        if fn in fns:
            return kind, why
    return None, None


def _collect(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    leaves = jax.tree.leaves(args)
    taint = [hasattr(leaf, "dtype") and
             jnp.issubdtype(leaf.dtype, jnp.integer) for leaf in leaves]
    hits = []
    _tainted_float_casts(jaxpr.jaxpr, taint, hits)
    return hits


def check_residency(engine, x, strict: bool = False) -> PassResult:
    """Residency lint over the plan's forward program(s) at input ``x``.

    ``strict=True`` asserts the full-integer claim (see module
    docstring): non-executing plans and whole-tensor float weight views
    become violations, and ``float_leak_count`` must be zero."""
    from repro.core import quant

    findings = []
    metrics = {"float_leak_count": 0, "descale_sites": 0}
    claims = engine.backend.int_resident
    holds = engine.int_resident
    if claims and not holds:
        findings.append(Finding(
            "warning", "residency-claim",
            f"backend {engine.backend_name!r} registers int_resident but the "
            "deployed tree holds no stored-integer leaves (family "
            f"{engine.exec_cfg.family!r} falls back to dequantise-first)"))
    if strict and not engine.int_exec:
        findings.append(Finding(
            "violation", "strict-mode",
            f"strict residency demands an integer-executing plan; "
            f"backend {engine.backend_name!r} planned "
            f"{'resident (dequantise-per-call)' if holds else 'float'} "
            "execution"))
    if not holds:
        findings.append(Finding(
            "info", "residency-claim",
            "plan deploys a float tree; no integer storage to leak"))
        return PassResult("residency", findings, metrics)

    if engine.int_exec:
        # Integer-executing plans run the model straight on the packed
        # tree: there is no per-call unpack stage to leak through, so
        # float_leak_count is zero by construction.
        findings.append(Finding(
            "info", "unpack-stage",
            "no unpack stage: the plan is integer-executing (the model "
            "consumes the packed tree directly)"))
    else:
        # (a) the separate unpack stage the Engine executes per call
        unpack_hits = _collect(quant.dequantize_tree, engine.params)
        metrics["float_leak_count"] = len(unpack_hits)
        findings.append(Finding(
            "whitelisted", "unpack-stage",
            f"{len(unpack_hits)} int->float cast(s) in the separate jitted "
            "unpack stage (Engine.live_params): the plan is integer-RESIDENT "
            "but not integer-EXECUTING — the per-call float materialisation "
            "the int-exec plan flavour eliminates"))

    # (b) the in-module resident program: forward on the packed tree
    cfg = engine.exec_cfg
    mod = engine._mod
    programs = [("forward", lambda p, xx: mod.forward(p, xx, cfg), x)]
    if cfg.family == "kwt":
        t = cfg.input_dim[1]
        frames = jnp.zeros((x.shape[0], t, cfg.input_dim[0]), jnp.float32)
        window = jnp.zeros((x.shape[0], t, cfg.d_model), jnp.float32)
        programs += [
            ("embed_frames", lambda p, fr: mod.embed_frames(p, fr, cfg),
             frames),
            ("encode_window", lambda p, w: mod.encode_window(p, w, cfg),
             window),
        ]
    for prog_name, fn, arg in programs:
        for eqn in _collect(fn, engine.params, arg):
            kind, why = _classify(eqn)
            if (strict and kind == "weight-descale"
                    and "qt_einsum" in jw.frame_functions(eqn)):
                # A whole-tensor descale feeding a float einsum: the
                # qt_einsum fallback path.  Plain resident mode sanctions
                # it; under the full-integer claim it is a leak (only
                # additive-consumption descales, e.g. positional tables,
                # stay whitelisted).
                kind = None
            src, dst = eqn.invars[0].aval, eqn.outvars[0].aval
            desc = (f"{prog_name}: {src.dtype}{list(src.shape)} -> "
                    f"{dst.dtype}")
            if kind == "weight-descale":
                metrics["descale_sites"] += 1
                findings.append(Finding("whitelisted", kind,
                                        f"{desc} — {why}", jw.user_site(eqn)))
            elif kind is not None:
                findings.append(Finding("whitelisted", kind,
                                        f"{desc} — {why}", jw.user_site(eqn)))
            else:
                findings.append(Finding(
                    "violation", "float-leak",
                    f"{desc}: unsanctioned dequantisation reachable from "
                    "packed weight storage", jw.user_site(eqn)))
    return PassResult("residency", findings, metrics)
