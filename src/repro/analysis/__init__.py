"""repro.analysis — jaxpr-level static verification of Engine plans.

The paper's headline claims are *static* properties — integer-resident
weights, overflow-free Q8.24 pipelines, a 64 kB RAM fit — yet before
this subsystem the repo enforced them only with example-based runtime
tests.  ``check_engine`` traces an Engine's jitted programs with
``jax.make_jaxpr`` and runs four passes over the equations:

  residency  - taint walk proving/refuting ``Backend.int_resident``
               (``analysis.residency``)
  ranges     - Q8.24 interval analysis flagging int32 overflow and
               ``fixed_mul`` precondition violations (``analysis.ranges``)
  budget     - ROM + LUT + peak-activation live-set vs the paper's
               64 kB target (``analysis.budget``)
  geometry   - Pallas block-shape / VMEM validation (``analysis.geometry``)

CLI::

    python -m repro.analysis check --config kwt_tiny --backend lut

The checker is self-testing: ``analysis.mutations`` seeds a float leak /
a wrapping shift / an oversized LUT bank, and the CI mutation step (plus
tests/test_analysis.py) asserts each one flips the verdict to FAIL.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.analysis.report import Finding, PassResult, Report  # noqa: F401

PASSES = ("residency", "ranges", "budget", "geometry")


def example_input(cfg, batch: int = 1):
    """A representative input for tracing ``cfg``'s forward program."""
    if cfg.family == "kwt":
        f, t = cfg.input_dim
        return jnp.zeros((batch, f, t), jnp.float32)
    return jnp.zeros((batch, 8), jnp.int32)


def check_engine(engine, x=None, passes=PASSES,
                 budget: int | None = None, strict: bool = False) -> Report:
    """Run the pass pipeline over one Engine plan.

    ``strict=True`` hardens the residency pass into the full-integer
    gate: the plan must be integer-executing with ``float_leak_count``
    zero and no whole-tensor float weight views (residency module
    docstring).

    Caches the one-line verdict on the Engine so ``describe()`` reports
    it (``Engine.describe(analyze=True)`` calls back into here).
    """
    from repro.analysis import budget as budget_pass
    from repro.analysis import geometry, ranges, residency

    if x is None:
        x = example_input(engine.exec_cfg)
    results = []
    for name in passes:
        if name == "residency":
            results.append(residency.check_residency(engine, x,
                                                     strict=strict))
        elif name == "ranges":
            results.append(ranges.check_ranges(engine, x))
        elif name == "budget":
            results.append(budget_pass.check_budget(engine, x, budget))
        elif name == "geometry":
            results.append(geometry.check_geometry(engine, x))
        else:
            raise ValueError(f"unknown analysis pass {name!r}")
    report = Report(engine.describe(), results)
    engine._analysis_verdict = report.verdict()
    return report
