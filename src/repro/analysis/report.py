"""Finding / PassResult / Report: the analysis subsystem's output types.

Every pass (residency, ranges, budget, geometry) emits one ``PassResult``
holding a list of ``Finding``s.  Severity semantics:

  ``violation``   - the pass refutes an invariant; the check FAILS.
  ``whitelisted`` - a known/sanctioned occurrence of the flagged pattern
                    (e.g. the lut backend's unpack-stage float casts, the
                    reciprocal's mantissa-normalisation shift), reported
                    with its justification but not fatal.
  ``assumption``  - a declared domain fact the pass relied on (e.g. the
                    dominant-lane row-sum >= 1 bound); reported so the
                    proof's trust base is explicit.
  ``warning``     - suspicious but not gating for this plan (e.g. Mosaic
                    tile-alignment notes on an interpret-mode plan).
  ``info``        - measurement lines (budget tables, kernel geometry).
"""

from __future__ import annotations

import dataclasses

SEVERITIES = ("violation", "whitelisted", "assumption", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    severity: str                 # one of SEVERITIES
    kind: str                     # e.g. "float-leak", "int32-overflow"
    message: str
    site: str = ""                # "function (file.py:line)" when known

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity

    def render(self) -> str:
        where = f"  @ {self.site}" if self.site else ""
        return f"[{self.severity}] {self.kind}: {self.message}{where}"


@dataclasses.dataclass
class PassResult:
    name: str                     # residency | ranges | budget | geometry
    findings: list
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(f.severity == "violation" for f in self.findings)

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    def render(self) -> str:
        head = f"-- {self.name}: {'PASS' if self.ok else 'FAIL'}"
        if self.metrics:
            head += "  (" + ", ".join(
                f"{k}={v}" for k, v in self.metrics.items()) + ")"
        return "\n".join([head] + ["   " + f.render() for f in self.findings])


@dataclasses.dataclass
class Report:
    """All pass results for one Engine plan."""

    engine_desc: str
    results: list                 # [PassResult]

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    def result(self, name: str) -> PassResult:
        for r in self.results:
            if r.name == name:
                return r
        raise KeyError(name)

    def verdict(self) -> str:
        """One-line summary (what Engine.describe appends)."""
        if self.ok:
            parts = []
            res = {r.name: r for r in self.results}
            if "residency" in res:
                parts.append(
                    f"leaks {res['residency'].metrics.get('float_leak_count', 0)}"
                    " whitelisted")
            if "budget" in res:
                m = res["budget"].metrics
                tot, cap = m.get("total_bytes"), m.get("budget_bytes")
                parts.append(f"ram {tot}/{cap} B" if cap else f"ram {tot} B")
            return "analysis: ok (" + ", ".join(parts) + ")"
        bad = ",".join(r.name for r in self.results if not r.ok)
        return f"analysis: FAIL({bad})"

    def render(self) -> str:
        return "\n".join([self.engine_desc] +
                         [r.render() for r in self.results] +
                         [self.verdict()])
