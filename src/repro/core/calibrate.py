"""Scale-factor calibration (paper §IV, Table V).

The paper chooses 2^y by sweeping candidate exponents for weights and inputs
and measuring end-task accuracy on the GSC dataset.  This module reproduces
that loop generically: given a model apply-fn, a parameter tree, and a
calibration batch iterator, sweep (weight_exp, input_exp) pairs and report
accuracy per pair — the Table V generator.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.core import quant


@dataclasses.dataclass
class SweepResult:
    weight_exponent: int
    input_exponent: int
    accuracy: float
    quantized_bytes: int


def quantize_inputs(x: jnp.ndarray, input_exponent: int) -> jnp.ndarray:
    """Quantise-dequantise the input at 2^y (static input quantisation)."""
    q = quant.quantize_po2(x, input_exponent, bits=8)
    return q.dequantize()


def sweep_scale_factors(
    apply_fn: Callable[..., jnp.ndarray],
    params,
    batches: Iterable[tuple[jnp.ndarray, jnp.ndarray]],
    weight_exponents: tuple[int, ...] = (3, 4, 5, 6),
    input_exponents: tuple[int, ...] = (3, 4, 5, 6),
    pairs: list[tuple[int, int]] | None = None,
    rounding: str = "nearest",
    bits: int = 8,
) -> list[SweepResult]:
    """Reproduce Table V: accuracy per (weight 2^y, input 2^y) pair.

    ``apply_fn(params, x) -> logits``.  Batches are (x, labels).
    The paper sweeps (8,8), (16,16), (32,32), (64,32), (64,64); pass those
    via ``pairs`` as exponents [(3,3),(4,4),(5,5),(6,5),(6,6)].
    ``rounding="floor"`` sweeps with the bit-exact eq-9 cast; ``bits``
    selects the stored width (``SweepResult.quantized_bytes`` then reports
    the TRUE packed bytes — nibble-packed at 4 bits).
    """
    if pairs is None:
        pairs = [(w, i) for w in weight_exponents for i in input_exponents]
    batches = list(batches)
    results = []
    for wexp, iexp in pairs:
        qparams = quant.quantize_tree(params, weight_exponent=wexp,
                                      rounding=rounding, bits=bits)
        fparams = quant.dequantize_tree(qparams)
        qbytes, _ = quant.tree_quantized_bytes(qparams)
        correct = total = 0
        fn = jax.jit(apply_fn)
        for x, y in batches:
            logits = fn(fparams, quantize_inputs(x, iexp))
            pred = jnp.argmax(logits, axis=-1)
            correct += int(jnp.sum(pred == y))
            total += int(y.size)
        results.append(SweepResult(wexp, iexp, correct / max(total, 1), qbytes))
    return results


def best_pair(results: list[SweepResult]) -> SweepResult:
    return max(results, key=lambda r: r.accuracy)
