"""LUT-approximated nonlinearities (paper §VI) as composable JAX functions.

These are the *reference* (pure-jnp) realisations of the paper's five custom
ALU behaviours (Table VII), in both float32 and Q8.24 fixed-point.  The
Pallas kernels in ``repro.kernels`` execute the same math tile-by-tile and
are verified against these functions.

Dispatch contract used across the framework:

    approx.softmax(x, mode=...)   mode in {"exact", "lut", "lut_fixed"}
    approx.gelu(x, mode=...)      mode in {"exact", "lut", "lut_interp"}
    approx.silu(x, mode=...)      (beyond-paper: same bounded-domain method
                                   applied to SiLU-family archs; DESIGN §3)

"exact"      - standard float op (the paper's un-accelerated C path).
"lut"        - float LUT gather (tables identical to the ROM contents).
"lut_fixed"  - full Q8.24 integer pipeline (the "+Hardware" path, Table IX).
"pallas"     - the same Q8.24 pipeline executed by the Pallas kernels in
               ``repro.kernels`` (interpret vs Mosaic is the ``interpret``
               argument, pinned once at plan time by ``repro.runtime`` via
               ``cfg.kernel_interpret`` — never probed per call).

Every non-exact mode is wrapped in a straight-through estimator
(:func:`ste`): the forward value is the approx pipeline verbatim
(bit-identical — custom_vjp primals trace the same ops), while
``jax.grad`` sees the exact float op's vjp.  This is what lets
``repro.qat`` put the deployed LUT numerics inside the training loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core import lut as lutlib
from repro.telemetry import taps as _health


def ste(primal_fn, smooth_fn):
    """Straight-through estimator: forward is ``primal_fn`` verbatim (the
    LUT / fixed-point / kernel pipeline, bit-identical to calling it
    directly), backward is the vjp of ``smooth_fn`` (the exact float op)
    evaluated at the same input.

    This is what makes every approx mode usable inside ``jax.grad``
    (repro.qat trains through the deployed numerics): the LUT gathers and
    integer ops have zero/undefined gradients, so QAT follows the standard
    STE reading — quantised forward, smooth backward (arXiv:2009.04465).

    ``primal_fn``/``smooth_fn`` must not close over traced values — a
    captured tracer escapes the custom_vjp when the bwd re-runs under
    ``jax.remat``/``scan``.  Operands beyond ``x`` (e.g. the attention
    mask) go through :func:`ste_masked` as explicit arguments.
    """
    @jax.custom_vjp
    def f(x):
        return primal_fn(x)

    def fwd(x):
        return primal_fn(x), x

    def bwd(x, g):
        _, vjp = jax.vjp(smooth_fn, x)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def ste_masked(primal_fn, smooth_fn):
    """STE over ``(x, mask)``: the (possibly traced) boolean mask is an
    explicit non-differentiable operand — closing over it instead leaks
    the tracer out of the custom_vjp under ``jax.remat``/``scan`` (the
    LM QAT train step rematerialises every block).  Its cotangent is the
    float0 zero JAX expects for bool primals."""
    @jax.custom_vjp
    def f(x, mask):
        return primal_fn(x, mask)

    def fwd(x, mask):
        return primal_fn(x, mask), (x, mask)

    def bwd(res, g):
        x, mask = res
        _, vjp = jax.vjp(lambda v: smooth_fn(v, mask), x)
        return vjp(g)[0], np.zeros(mask.shape, jax.dtypes.float0)

    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# SoftMax (paper eqs 2, 10, 11, 12)
# ---------------------------------------------------------------------------

def softmax_exact(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis)


def _pre_shift(num_q: jnp.ndarray, pre: int) -> jnp.ndarray:
    """Round-to-nearest right shift of the Q8.24 numerators.  Truncating
    here instead biases every lane low by ~2^{pre-1}, which deflates the
    row sum and turns into a +8% normalisation overshoot at K=32k."""
    if pre <= 0:
        return num_q
    return (num_q + (1 << (pre - 1))) >> pre


def softmax_lut(x: jnp.ndarray, axis: int = -1, *, fixed: bool = False,
                range_reduce: bool = True,
                bank: lutlib.LutBank | None = None) -> jnp.ndarray:
    """Max-normalised LUT softmax (eq 10 with the eq-11/12 tables).

    z_i = clip(max(x) - x_i, 0, 10);  num_i = LUT_EXP[z_i*32]
    s = sum_i num_i;                  out_i = num_i * LUT_INV-based 1/s
    """
    bank = bank or lutlib.make_lut_bank()
    x = x.astype(jnp.float32)
    z = jnp.clip(jnp.max(x, axis=axis, keepdims=True) - x, 0.0, lutlib.EXP_RANGE)
    if not fixed:
        num = jnp.take(jnp.asarray(bank.exp_f32),
                       jnp.clip((z * lutlib.BINS_PER_UNIT).astype(jnp.int32),
                                0, lutlib.N_EXP_ENTRIES - 1))
        s = jnp.sum(num, axis=axis, keepdims=True)
        if range_reduce:
            inv = 1.0 / s  # float path: true division, LUT only for exp
        else:
            inv = jnp.take(jnp.asarray(bank.inv_f32),
                           lutlib.inv_index_from_q24(fxp.to_fixed(s)))
        return num * inv

    # Q8.24 integer pipeline: ALU_TO_FIXED -> ALU_EXP -> sum -> ALU_INVERT
    # -> fixed multiply -> ALU_TO_FLOAT.  Matches the C loop in §VI.
    #
    # The paper's int32 accumulator holds sums up to K=SEQLEN=27 in Q8.24;
    # beyond K=127 it would overflow.  For framework sequence lengths we
    # pre-shift the numerators by `pre` bits so the row sum stays in int32,
    # and compensate in the reciprocal (1/(s<<pre) == (1/s)>>pre).  pre==0
    # reproduces the paper bit-exactly at its own scales.
    k_len = x.shape[axis]
    pre = max(0, int(np.ceil(np.log2(max(k_len, 1)))) - 6)
    z_q = fxp.to_fixed(z)
    num_q = jnp.take(jnp.asarray(bank.exp_q24),
                     lutlib.exp_index_from_q24(z_q))             # in [0, 1]
    s_q = jnp.sum(_pre_shift(num_q, pre), axis=axis, keepdims=True)  # Q8.(24-pre)
    inv_q = lutlib.reciprocal_q24(s_q, bank, range_reduce=range_reduce)
    inv_q = inv_q >> pre                                          # back to Q8.24
    out_q = fxp.fixed_mul(num_q, inv_q, nonneg=True)
    return fxp.to_float(out_q)


def softmax(x: jnp.ndarray, axis: int = -1, mode: str = "exact",
            interpret: bool = True, **kw) -> jnp.ndarray:
    # quantisation-health tap (telemetry.taps): trace-time no-op unless an
    # Engine taps program is collecting.  Placed in the dispatcher — never
    # inside the ste() custom_vjp primal, whose inner trace's tracers must
    # not leak into the aux output.
    if _health.active() and axis in (-1, x.ndim - 1):
        _health.tap_softmax(x, None, fixed=mode in ("lut_fixed", "pallas"))
    if mode == "exact":
        return softmax_exact(x, axis)
    if mode == "lut":
        primal = lambda v: softmax_lut(v, axis, fixed=False, **kw)
    elif mode == "lut_fixed":
        primal = lambda v: softmax_lut(v, axis, fixed=True, **kw)
    elif mode == "pallas":
        assert axis in (-1, x.ndim - 1), "pallas softmax reduces the last axis"
        from repro.kernels import ops
        primal = lambda v: ops.lut_softmax(v, fixed=True, interpret=interpret)
    else:
        raise ValueError(f"unknown softmax mode {mode!r}")
    return ste(primal, lambda v: softmax_exact(v, axis))(x)


def masked_softmax(s: jnp.ndarray, mask: jnp.ndarray | None,
                   mode: str = "exact", interpret: bool = True) -> jnp.ndarray:
    """Softmax over the last axis with *structural* masking.

    For the LUT modes, masked lanes are excluded from the numerator sum
    (they never reach the ROM), mirroring the paper's C pipeline which only
    computes valid entries — not approximated to e^{-10} by the clip.
    Rows that are fully masked return zeros.
    """
    if _health.active():   # health tap; see softmax() for placement notes
        _health.tap_softmax(s, mask, fixed=mode in ("lut_fixed", "pallas"))
    if mode == "exact" and s.dtype == jnp.bfloat16:
        # dtype-preserving path: the materialised score/prob tensors stay
        # bf16 (halved HBM traffic — §Perf H1); row stats reduce in f32.
        neg = jnp.asarray(jnp.finfo(jnp.float32).min, jnp.bfloat16)
        sm = s if mask is None else jnp.where(mask, s, neg)
        m = jnp.max(sm.astype(jnp.float32), axis=-1, keepdims=True)
        p = jnp.exp(sm - m.astype(jnp.bfloat16))
        if mask is not None:
            p = jnp.where(mask, p, 0)
        den = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        return p * (1.0 / jnp.maximum(den, 1e-30)).astype(jnp.bfloat16)
    s = s.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min

    def exact_f32(sv, mk):
        sm = sv if mk is None else jnp.where(mk, sv, neg)
        out = jax.nn.softmax(sm, axis=-1)
        return out if mk is None else jnp.where(mk, out, 0.0)

    if mode == "exact":
        return exact_f32(s, mask)
    if mode == "pallas":
        # Kernel path: unmasked rows are the Pallas LUT pipeline verbatim
        # (bit-identical to ops.lut_softmax).  With a mask, masked lanes
        # enter the kernel at the z=10 clip bin (the paper's own off-range
        # leak); we zero them and renormalise in f32, recovering the
        # structural exclusion of the jnp reference up to that rescale.
        from repro.kernels import ops

        def primal(sv, mk):
            sm = sv if mk is None else jnp.where(mk, sv, neg)
            out = ops.lut_softmax(sm, fixed=True, interpret=interpret)
            if mk is not None:
                out = jnp.where(mk, out, 0.0)
                out = out / jnp.maximum(
                    jnp.sum(out, axis=-1, keepdims=True), 1e-30)
            return out
    elif mode == "lut":
        def primal(sv, mk):
            bank = lutlib.make_lut_bank()
            sm = sv if mk is None else jnp.where(mk, sv, neg)
            m = jnp.max(sm, axis=-1, keepdims=True)
            z = jnp.clip(m - sv, 0.0, lutlib.EXP_RANGE)
            num = jnp.take(
                jnp.asarray(bank.exp_f32),
                jnp.clip((z * lutlib.BINS_PER_UNIT).astype(jnp.int32),
                         0, lutlib.N_EXP_ENTRIES - 1))
            if mk is not None:
                num = jnp.where(mk, num, 0.0)
            return num / jnp.maximum(
                jnp.sum(num, axis=-1, keepdims=True), 1e-30)
    elif mode == "lut_fixed":
        def primal(sv, mk):
            bank = lutlib.make_lut_bank()
            sm = sv if mk is None else jnp.where(mk, sv, neg)
            m = jnp.max(sm, axis=-1, keepdims=True)
            z = jnp.clip(m - sv, 0.0, lutlib.EXP_RANGE)
            k_len = sv.shape[-1]
            pre = max(0, int(np.ceil(np.log2(max(k_len, 1)))) - 6)
            z_q = fxp.to_fixed(z)
            num_q = jnp.take(jnp.asarray(bank.exp_q24),
                             lutlib.exp_index_from_q24(z_q))
            if mk is not None:
                num_q = jnp.where(mk, num_q, 0)
            s_q = jnp.sum(_pre_shift(num_q, pre), axis=-1, keepdims=True)
            s_q = jnp.maximum(s_q, 1)
            inv_q = lutlib.reciprocal_q24(s_q, bank) >> pre
            return fxp.to_float(fxp.fixed_mul(num_q, inv_q, nonneg=True))
    else:
        raise ValueError(f"unknown softmax mode {mode!r}")
    # STE: the approx pipeline verbatim in the forward pass, the exact
    # masked softmax's gradient in the backward pass (QAT trains through
    # the deployed numerics; see repro.qat).  The mask — often a tracer
    # built inside the same remat'd block — is an explicit operand, never
    # a closure capture.
    if mask is None:
        return ste(lambda sv: primal(sv, None),
                   lambda sv: exact_f32(sv, None))(s)
    return ste_masked(primal, exact_f32)(s, mask)


# ---------------------------------------------------------------------------
# GELU (paper eqs 7, 13, Fig 7)
# ---------------------------------------------------------------------------

def gelu_exact(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=False)


def gelu_lut(x: jnp.ndarray, *, interp: bool = False,
             bank: lutlib.LutBank | None = None) -> jnp.ndarray:
    """Piecewise GELU: x above 1.595, 0 below -1.857, 32-entry LUT between."""
    bank = bank or lutlib.make_lut_bank()
    x = x.astype(jnp.float32)
    if not interp:
        mid = jnp.take(jnp.asarray(bank.gelu_f32), lutlib.gelu_index_from_f32(x))
    else:
        # beyond-paper: linear interpolation between adjacent entries.
        n = lutlib.N_GELU_ENTRIES
        t = (x - lutlib.GELU_LO) * (float(n - 1) / (lutlib.GELU_HI - lutlib.GELU_LO))
        t = jnp.clip(t, 0.0, float(n - 1))
        i0 = jnp.clip(jnp.floor(t).astype(jnp.int32), 0, n - 2)
        frac = t - i0.astype(jnp.float32)
        tab = jnp.asarray(bank.gelu_f32)
        mid = jnp.take(tab, i0) * (1.0 - frac) + jnp.take(tab, i0 + 1) * frac
    return jnp.where(x > lutlib.GELU_HI, x,
                     jnp.where(x < lutlib.GELU_LO, 0.0, mid))


def gelu(x: jnp.ndarray, mode: str = "exact", interpret: bool = True,
         **kw) -> jnp.ndarray:
    if _health.active():   # health tap; see softmax() for placement notes
        _health.tap_gelu(x)
    if mode == "exact":
        return gelu_exact(x)
    if mode == "lut":
        primal = lambda v: gelu_lut(v, interp=False, **kw)
    elif mode == "lut_interp":
        primal = lambda v: gelu_lut(v, interp=True, **kw)
    elif mode == "pallas":
        from repro.kernels import ops
        primal = lambda v: ops.lut_gelu(v, interpret=interpret)
    else:
        raise ValueError(f"unknown gelu mode {mode!r}")
    return ste(primal, gelu_exact)(x)


# ---------------------------------------------------------------------------
# Beyond-paper: the same bounded-domain LUT method for SiLU / sigmoid /
# softplus, covering the assigned SiLU-family and SSM archs (DESIGN §3).
# ---------------------------------------------------------------------------

_SIG_RANGE = 8.0
_SIG_ENTRIES = 256


def _sigmoid_table() -> jnp.ndarray:
    import numpy as np

    z = np.linspace(-_SIG_RANGE, _SIG_RANGE, _SIG_ENTRIES)
    return jnp.asarray(1.0 / (1.0 + np.exp(-z)), jnp.float32)


def sigmoid_lut(x: jnp.ndarray) -> jnp.ndarray:
    tab = _sigmoid_table()
    t = (x.astype(jnp.float32) + _SIG_RANGE) * ((_SIG_ENTRIES - 1) / (2 * _SIG_RANGE))
    idx = jnp.clip(jnp.round(t).astype(jnp.int32), 0, _SIG_ENTRIES - 1)
    mid = tab[idx]
    return jnp.where(x > _SIG_RANGE, 1.0, jnp.where(x < -_SIG_RANGE, 0.0, mid))


def silu(x: jnp.ndarray, mode: str = "exact") -> jnp.ndarray:
    if mode == "exact":
        return jax.nn.silu(x.astype(jnp.float32))
    return ste(lambda v: v.astype(jnp.float32) * sigmoid_lut(v),
               lambda v: jax.nn.silu(v.astype(jnp.float32)))(x)


def softplus(x: jnp.ndarray, mode: str = "exact") -> jnp.ndarray:
    if mode == "exact":
        return jax.nn.softplus(x.astype(jnp.float32))
    # softplus(x) = x + softplus(-x); bounded branch via -log(sigmoid(-x)).
    return jnp.where(x > _SIG_RANGE, x.astype(jnp.float32),
                     -jnp.log(jnp.maximum(sigmoid_lut(-x), 1e-12)))


def sqrelu(x: jnp.ndarray) -> jnp.ndarray:
    """Squared ReLU (nemotron-4).  Cheap polynomial; no LUT needed (DESIGN §3)."""
    r = jnp.maximum(x, 0.0)
    return r * r


def activation(name: str, mode: str = "exact", interpret: bool = True):
    """Resolve an activation by config name, honouring the approx mode.
    ``interpret`` only applies to the pallas kernel mode (pinned at plan
    time by repro.runtime); SiLU-family pallas requests fall back to the
    jnp LUT reference (the paper's kernel set covers GELU + softmax)."""
    if name == "gelu":
        if mode == "pallas":
            return lambda x: gelu(x, mode="pallas", interpret=interpret)
        return lambda x: gelu(x, mode="lut" if mode != "exact" else "exact")
    if name == "silu":
        return lambda x: silu(x, mode="lut" if mode == "pallas" else mode)
    if name == "sqrelu":
        return lambda x: sqrelu(x)
    if name == "relu":
        return lambda x: jnp.maximum(x, 0.0)
    raise ValueError(f"unknown activation {name!r}")
