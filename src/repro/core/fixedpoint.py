"""Q8.24 fixed-point arithmetic (paper §VI, ALU_TO_FIXED / ALU_TO_FLOAT).

The paper's custom RISC-V ALU operates on Q8.24 integers: a signed 32-bit
integer whose low 24 bits are the fraction.  Representable range is
[-128, 128) with resolution 2^-24.

On TPU these become element-wise VPU integer ops; everything here is
jit-able, vectorised jnp, and is also executed verbatim inside Pallas
kernel bodies (interpret mode on CPU, compiled on TPU).

int64 is unavailable without x64 mode, so the Q8.24 × Q8.24 product uses a
12/12-bit limb decomposition (`fixed_mul`) that is exact whenever both
magnitudes fit in 24 bits (i.e. values in [0, 1) after normalisation) —
precisely the domain the paper's SoftMax pipeline produces (e^{-z} ∈ [0,1],
1/sum ∈ (0,1]).
"""

from __future__ import annotations

import jax.numpy as jnp

FRAC_BITS = 24
ONE = 1 << FRAC_BITS  # 1.0 in Q8.24
_INT32_MAX = jnp.int32(2**31 - 1)
_INT32_MIN = jnp.int32(-(2**31))


def to_fixed(x: jnp.ndarray) -> jnp.ndarray:
    """ALU_TO_FIXED: float -> Q8.24 int32 (round-to-nearest, saturating)."""
    scaled = jnp.asarray(x, jnp.float32) * float(ONE)
    scaled = jnp.clip(scaled, float(_INT32_MIN), float(_INT32_MAX))
    return jnp.round(scaled).astype(jnp.int32)


def to_float(q: jnp.ndarray) -> jnp.ndarray:
    """ALU_TO_FLOAT: Q8.24 int32 -> float32."""
    return q.astype(jnp.float32) * (1.0 / float(ONE))


def fixed_mul(a: jnp.ndarray, b: jnp.ndarray, *,
              nonneg: bool = False) -> jnp.ndarray:
    """Q8.24 multiply, exact for |a|,|b| <= 1.0 (24-bit magnitudes).

    (a * b) >> 24 via 12/12 limb split so every partial product fits int32:
      a = ah*2^12 + al,  b = bh*2^12 + bl   (ah,bh <= 2^12 when |x|<=1)
      (a*b)>>24 = ah*bh + ((ah*bl + al*bh) >> 12) + ((al*bl) >> 24)

    ``nonneg=True`` asserts both operands are >= 0 (the SoftMax
    normalise: e^{-z} in [0,1] times 1/sum in (0,1]) and skips the
    sign/abs handling — identical results on that domain, ~40% fewer
    VPU ops on the hot [*, K, K] normalise.
    """
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    if nonneg:
        ah, al = a32 >> 12, a32 & 0xFFF
        bh, bl = b32 >> 12, b32 & 0xFFF
        prod = ah * bh + ((ah * bl + al * bh) >> 12) + ((al * bl) >> 24)
        return prod.astype(jnp.int32)
    sign = jnp.sign(a32) * jnp.sign(b32)
    ma = jnp.abs(a32)
    mb = jnp.abs(b32)
    ah, al = ma >> 12, ma & 0xFFF
    bh, bl = mb >> 12, mb & 0xFFF
    prod = ah * bh + ((ah * bl + al * bh) >> 12) + ((al * bl) >> 24)
    return (sign * prod).astype(jnp.int32)


def fixed_shift_mul(a: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Multiply a Q8.24 value by 2^shift (the paper's power-of-2 rescale).

    The left-shift path saturates like ``to_fixed`` does: ``a << shift``
    on int32 silently wraps once |a| >= 2^(31-shift), and a wrapped
    rescale flips the sign of the largest activations.  Values past the
    representable range pin to the int32 extremes instead.
    """
    a = a.astype(jnp.int32)
    if shift == 0:
        return a
    if shift < 0:
        return (a >> (-shift)).astype(jnp.int32)
    hi_lim = _INT32_MAX >> shift
    lo_lim = _INT32_MIN >> shift
    return jnp.where(a > hi_lim, _INT32_MAX,
                     jnp.where(a < lo_lim, _INT32_MIN,
                               a << shift)).astype(jnp.int32)


def ilog2(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(x)) for positive int32 x, as a fixed compare ladder
    (no loops / no clz instruction -> TPU VPU friendly).

    Used by the range-reduced reciprocal (lut.reciprocal_q24): a Q8.24
    value x is normalised to m = x * 2^-t in [1, 2) with t = ilog2(x) - 24.
    """
    x = x.astype(jnp.int32)
    k = jnp.zeros_like(x)
    for step in (16, 8, 4, 2, 1):
        cond = x >= (jnp.int32(1) << step)
        k = jnp.where(cond, k + step, k)
        x = jnp.where(cond, x >> step, x)
    return k
