"""Core: the paper's contribution as composable JAX modules.

fixedpoint  - Q8.24 arithmetic (ALU_TO_FIXED / ALU_TO_FLOAT)
lut         - the 2.69 kB ROM tables (eqs 11-13)
approx      - LUT softmax / GELU / SiLU dispatchers (Table VII behaviours)
quant       - power-of-2 PTQ (eq 9), QTensor, integer matmul
calibrate   - Table V scale-factor sweep
"""

from repro.core import approx, calibrate, fixedpoint, lut, quant  # noqa: F401
