"""Lookup-table construction (paper §VI, eqs 11-13, Table VII).

Three ROM tables, identical contents/sizes to the paper's:

  LUT_EXP  (ALU_EXP):    320 entries, e^{-z} for z in [0, 10), 32 bins/unit
                         -> LUT1[z*32] ~= 1/e^z              (eq 11)
  LUT_INV  (ALU_INVERT): 320 entries, 1/z for z in (0, 10], 32 bins/unit
                         -> LUT2[z*32 - 1] ~= 1/z            (eq 12)
  LUT_GELU (ALU_GELU):   32 entries over [-1.857, 1.595]     (eq 13, Fig 7)
                         identity tail above 1.595, zero tail below -1.857

Total ROM = (320+320)*4B + 32*4B = 2.69 kB, matching the paper's figure.

Tables are materialised both as float32 (the framework's float path) and as
Q8.24 int32 (the fixed-point path executed inside the Pallas kernels).
Construction is pure numpy at trace time; the tables enter jit as constants
and live in VMEM inside kernels.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np
import jax.numpy as jnp

from repro.core import fixedpoint as fxp

EXP_RANGE = 10.0          # paper: "all values of e^{max(x)-x_i} lie between 0 and 10"
BINS_PER_UNIT = 32        # paper: "32 divisions per unit"
N_EXP_ENTRIES = int(EXP_RANGE * BINS_PER_UNIT)   # 320
N_GELU_ENTRIES = 32
GELU_HI = 1.595           # GELU(x) = x above this           (paper Fig 7)
GELU_LO = -1.857          # GELU(x) = 0 below this


@dataclasses.dataclass(frozen=True)
class LutBank:
    """The paper's 2.69 kB ROM bank.

    Held as *numpy* arrays (safe to lru_cache across jit traces; they enter
    each trace as fresh constants via jnp.take / jnp.asarray at use sites).
    """

    exp_f32: np.ndarray    # [320] e^{-i/32}
    inv_f32: np.ndarray    # [320] 32/(i+1)  == 1/z at z=(i+1)/32
    gelu_f32: np.ndarray   # [32]  GELU on linspace(GELU_LO, GELU_HI, 32)
    exp_q24: np.ndarray    # int32 Q8.24 versions of the same
    inv_q24: np.ndarray
    gelu_q24: np.ndarray

    @property
    def rom_bytes(self) -> int:
        return 4 * (self.exp_f32.size + self.inv_f32.size + self.gelu_f32.size)


def _gelu_exact_np(x: np.ndarray) -> np.ndarray:
    # erf via numpy to avoid a scipy dependency: use the identity with
    # math.erf vectorised (exact, not tanh-approximated -- paper eq 7).
    import math

    return np.asarray(
        [xi * 0.5 * (1.0 + math.erf(xi / math.sqrt(2.0))) for xi in np.ravel(x)],
        dtype=np.float64,
    ).reshape(np.shape(x))


@lru_cache(maxsize=4)
def make_lut_bank(bins_per_unit: int = BINS_PER_UNIT,
                  exp_range: float = EXP_RANGE,
                  n_gelu: int = N_GELU_ENTRIES) -> LutBank:
    n_exp = int(exp_range * bins_per_unit)
    # eq 11: LUT1[z*32] ~= e^{-z};  entry i corresponds to z = i/32.
    z = np.arange(n_exp, dtype=np.float64) / bins_per_unit
    exp_tab = np.exp(-z)
    # eq 12: LUT2[z*32 - 1] ~= 1/z; entry i corresponds to z = (i+1)/32.
    zi = (np.arange(n_exp, dtype=np.float64) + 1.0) / bins_per_unit
    inv_tab = 1.0 / zi
    # eq 13: 32 GELU samples across the paper's near-optimal thresholds.
    xg = np.linspace(GELU_LO, GELU_HI, n_gelu)
    gelu_tab = _gelu_exact_np(xg)

    def q24(a):
        return np.round(a * (1 << fxp.FRAC_BITS)).astype(np.int32)

    return LutBank(
        exp_f32=np.asarray(exp_tab, np.float32),
        inv_f32=np.asarray(inv_tab, np.float32),
        gelu_f32=np.asarray(gelu_tab, np.float32),
        exp_q24=q24(exp_tab),
        inv_q24=q24(inv_tab),
        gelu_q24=q24(gelu_tab),
    )


# ---------------------------------------------------------------------------
# Index computations (shared by jnp reference path and Pallas kernels).
# ---------------------------------------------------------------------------

def exp_index_from_q24(z_q: jnp.ndarray, bins_per_unit: int = BINS_PER_UNIT) -> jnp.ndarray:
    """Index into LUT_EXP for Q8.24 z >= 0.  i = z*32 == z_q >> (24-5)."""
    shift = fxp.FRAC_BITS - int(np.log2(bins_per_unit))
    idx = (z_q >> shift).astype(jnp.int32)
    return jnp.clip(idx, 0, N_EXP_ENTRIES - 1)


def inv_index_from_q24(s_q: jnp.ndarray, bins_per_unit: int = BINS_PER_UNIT) -> jnp.ndarray:
    """Index into LUT_INV for Q8.24 s > 0.  i = s*32 - 1 (eq 12)."""
    shift = fxp.FRAC_BITS - int(np.log2(bins_per_unit))
    idx = (s_q >> shift).astype(jnp.int32) - 1
    return jnp.clip(idx, 0, N_EXP_ENTRIES - 1)


def gelu_index_from_f32(x: jnp.ndarray, n: int = N_GELU_ENTRIES) -> jnp.ndarray:
    t = (x - GELU_LO) * (float(n - 1) / (GELU_HI - GELU_LO))
    return jnp.clip(jnp.round(t).astype(jnp.int32), 0, n - 1)


def reciprocal_q24(s_q: jnp.ndarray, bank: LutBank, range_reduce: bool = True) -> jnp.ndarray:
    """1/s for Q8.24 s >= 1, via LUT_INV.

    Paper-faithful mode (range_reduce=False) indexes the (0,10] table
    directly and clamps -- exact reproduction of eq 12, including its
    saturation for sums > 10.

    range_reduce=True (beyond-paper robustness, noted in DESIGN.md):
    normalise s = m * 2^k with m in [1,2), look up 1/m, shift back.
    Needed for softmax over real sequence lengths (sum of e^{-z} over K
    keys can reach K >> 10; KWT-Tiny's own SEQLEN=27 already exceeds the
    table range when attention is flat).
    """
    if not range_reduce:
        return jnp.take(jnp.asarray(bank.inv_q24), inv_index_from_q24(s_q))
    t = fxp.ilog2(s_q) - fxp.FRAC_BITS          # s * 2^-t in [1, 2)
    tp = jnp.maximum(t, 0)
    tn = jnp.maximum(-t, 0)
    m = ((s_q >> tp) << tn).astype(jnp.int32)   # mantissa in [1, 2) Q8.24
    inv_m = jnp.take(jnp.asarray(bank.inv_q24), inv_index_from_q24(m))
    # (1/m) * 2^-t, saturating on the (rare) left-shift overflow path.
    limit = jnp.int32(2**31 - 1) >> tn
    return jnp.where(t >= 0, inv_m >> tp,
                     jnp.where(inv_m > limit, jnp.int32(2**31 - 1),
                               inv_m << tn)).astype(jnp.int32)
