"""Power-of-2 post-training static quantisation (paper §IV, eq 9, Table V).

    W_int = floor(W_float * 2^y), stored INT8, dequantised by bit shift.

Design points carried over from the paper:
  * scale factors are powers of two so (de)quantisation is a shift;
  * weights and inputs get *separate* exponents (Table V: 2^6 vs 2^5);
  * intermediate results of int matmuls accumulate wider (paper: INT16
    residuals; on TPU the MXU gives int32 accumulation for free, and we
    optionally clip back to int16 to reproduce the paper's storage type);
  * SoftMax and LayerNorm stay in float in the faithful path (§IV cites
    [12]: quantising them is "quite taxing on accuracy").

Beyond-paper (flagged, see DESIGN.md §5): per-channel exponents, int8
quantised Adam moments, int8 error-feedback gradient compression — the same
eq-9 primitive applied at other points of the stack.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

INT8_MIN, INT8_MAX = -128, 127
INT16_MIN, INT16_MAX = -(2**15), 2**15 - 1


def int_range(bits: int) -> tuple[int, int]:
    """The two's-complement range of a ``bits``-wide signed integer."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def storage_dtype(bits: int):
    """Narrowest container dtype for ``bits``-wide values.

    ``bits<=4`` values are *stored* nibble-packed (two per uint8 byte, see
    :func:`pack_po2`); their element dtype before packing is int8.
    """
    return jnp.int8 if bits <= 8 else jnp.int16


# ---------------------------------------------------------------------------
# The packed-int codec.  ONE implementation shared by Engine weights
# (integer-resident QTensors), QAT export artifacts (qat/export.py),
# compressed gradient payloads (dist/compress.py) and checkpoints.
# ---------------------------------------------------------------------------

def packed_length(n: int, bits: int) -> int:
    """Stored bytes for ``n`` values at ``bits`` width (nibble packing)."""
    return (n + 1) // 2 if bits <= 4 else n


def pack_po2(values: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack ``bits<=4`` two's-complement values, two nibbles per byte.

    ``values`` is any int array whose elements fit the ``bits``-wide range;
    the result is a flat uint8 array of ``ceil(n/2)`` bytes (low nibble =
    even index).  Odd lengths pad the final high nibble with zero; empty
    tensors pack to an empty byte string.  Exact inverse: :func:`unpack_po2`
    with the original shape — integers in, integers out, no float detour.
    """
    assert 1 <= bits <= 4, f"pack_po2 is the sub-byte codec (bits={bits})"
    flat = values.reshape(-1).astype(jnp.uint8)        # two's-complement wrap
    if flat.size % 2:
        flat = jnp.concatenate([flat, jnp.zeros((1,), jnp.uint8)])
    pairs = flat.reshape(-1, 2)
    return ((pairs[:, 0] & 0xF) | ((pairs[:, 1] & 0xF) << 4)).astype(jnp.uint8)


def unpack_po2(packed: jnp.ndarray, bits: int, shape) -> jnp.ndarray:
    """Inverse of :func:`pack_po2`: nibble-packed bytes -> int8 ``shape``.

    Sign-extends each 4-bit two's-complement nibble ((v ^ 8) - 8), so the
    round-trip is exact for every value in the ``bits``-wide range.
    """
    assert 1 <= bits <= 4, f"unpack_po2 is the sub-byte codec (bits={bits})"
    n = int(np.prod(shape, dtype=np.int64))
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    flat = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
    return ((flat.astype(jnp.int8) ^ 8) - 8).reshape(shape)


def pack_payload(values: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Storage form of an int tensor: nibble-packed for ``bits<=4``, the
    narrowest int dtype otherwise (the codec entry point non-QTensor
    callers — dist/compress payloads, export writers — share)."""
    if bits <= 4:
        return pack_po2(values, bits)
    return values.astype(storage_dtype(bits))


def unpack_payload(payload: jnp.ndarray, bits: int, shape) -> jnp.ndarray:
    """Inverse of :func:`pack_payload` (identity above 4 bits)."""
    if bits <= 4:
        return unpack_po2(payload, bits, shape)
    return payload.reshape(shape)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """An eq-9 quantised tensor: int values + static power-of-2 exponent.

    Storage is dtype-true (the bytes a 64 kB device would hold): int8 for
    ``4 < bits <= 8``, int16 above, and nibble-packed uint8 (two values
    per byte, :func:`pack_po2`) for ``bits <= 4``.  When packed,
    ``logical_shape`` carries the pre-pack shape and ``values`` is the
    flat byte image; :meth:`int_values` restores the int8 grid (inside
    jit too — unpacking is pure bit arithmetic).
    """

    values: jnp.ndarray               # int8 / int16, or uint8 nibble-packed
    exponent: int = dataclasses.field(metadata=dict(static=True))
    axis_exponents: jnp.ndarray | None = None    # per-channel (beyond-paper)
    bits: int = dataclasses.field(default=8, metadata=dict(static=True))
    logical_shape: tuple | None = dataclasses.field(
        default=None, metadata=dict(static=True))    # set iff nibble-packed

    @classmethod
    def store(cls, q: jnp.ndarray, exponent: int, *, bits: int = 8,
              axis_exponents: jnp.ndarray | None = None) -> "QTensor":
        """Build a dtype-true QTensor from an (already clipped) int grid."""
        qi = q.astype(storage_dtype(bits))     # signed cast BEFORE nibble wrap
        if bits <= 4:
            return cls(values=pack_po2(qi, bits), exponent=exponent,
                       axis_exponents=axis_exponents, bits=bits,
                       logical_shape=tuple(qi.shape))
        return cls(values=qi, exponent=exponent,
                   axis_exponents=axis_exponents, bits=bits)

    @property
    def packed(self) -> bool:
        return self.logical_shape is not None

    @property
    def shape(self):
        return self.logical_shape if self.packed else self.values.shape

    @property
    def stored_bytes(self) -> int:
        """True packed storage bytes (values + per-channel exponents)."""
        b = self.values.size * self.values.dtype.itemsize
        if self.axis_exponents is not None:
            b += self.axis_exponents.size * self.axis_exponents.dtype.itemsize
        return b

    def int_values(self) -> jnp.ndarray:
        """The integer grid at its logical shape (unpacks when packed)."""
        if self.packed:
            return unpack_po2(self.values, self.bits, self.logical_shape)
        return self.values

    def dequantize(self) -> jnp.ndarray:
        scale = jnp.float32(2.0 ** (-self.exponent))
        out = self.int_values().astype(jnp.float32) * scale
        if self.axis_exponents is not None:
            out = out * jnp.exp2(-self.axis_exponents.astype(jnp.float32))
        return out


def quantize_po2(w: jnp.ndarray, exponent: int, *, bits: int = 8,
                 stochastic_key: jax.Array | None = None,
                 rounding: str = "floor") -> QTensor:
    """eq 9: floor(w * 2^y) with saturation to the ``bits``-wide int range.

    ``rounding="nearest"`` adds the half-LSB offset before the floor (an
    adder in front of the truncating shift in hardware terms): floor's
    systematic -LSB/2 bias is correlated across every weight and measurably
    shifts whole-model logits; the offset removes it at zero ROM cost.

    Storage is the narrowest dtype for ``bits`` (int8 up to 8 bits,
    nibble-packed below 5 — no silent int16 widening), and saturation
    clips at the true ``bits``-wide edges (e.g. [-8, 7] at 4 bits).
    """
    lo, hi = int_range(bits)
    scaled = w.astype(jnp.float32) * (2.0 ** exponent)
    if rounding not in ("floor", "nearest"):
        raise ValueError(f"unknown rounding {rounding!r}")
    if stochastic_key is not None:  # beyond-paper: stochastic rounding option
        noise = jax.random.uniform(stochastic_key, w.shape)
        q = jnp.floor(scaled + noise)
    elif rounding == "nearest":
        q = jnp.floor(scaled + 0.5)
    else:
        q = jnp.floor(scaled)
    return QTensor.store(jnp.clip(q, lo, hi), exponent, bits=bits)


def choose_exponent(w: jnp.ndarray, *, bits: int = 8) -> int:
    """Largest y such that floor(max|w| * 2^y) does not saturate.

    The paper picks y by accuracy sweep (Table V); this is the analytic
    no-overflow bound used as the sweep's starting point.
    """
    import numpy as np

    maxabs = float(jnp.max(jnp.abs(w)))
    if maxabs == 0.0:
        return bits - 1
    return int(np.floor(np.log2((2 ** (bits - 1) - 1) / maxabs)))


def qmatmul(x: QTensor, w: QTensor, *, out_exponent: int | None = None,
            residual_bits: int = 16) -> QTensor:
    """Integer matmul with int32 accumulation and shift rescale.

    C_int32 = X_int8 @ W_int8 has exponent (x.e + w.e).  The result is
    shifted to ``out_exponent`` and clipped to the residual width (paper:
    INT16 intermediates).
    """
    xv, wv = x.int_values(), w.int_values()
    acc = jax.lax.dot_general(
        xv, wv,
        dimension_numbers=(((xv.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_exp = x.exponent + w.exponent
    out_exponent = acc_exp if out_exponent is None else out_exponent
    shift = acc_exp - out_exponent
    acc = jnp.where(shift >= 0, acc >> shift, acc << (-shift)) if isinstance(shift, jnp.ndarray) \
        else (acc >> shift if shift >= 0 else acc << (-shift))
    lo, hi = (INT16_MIN, INT16_MAX) if residual_bits == 16 else (-(2**31), 2**31 - 1)
    dtype = jnp.int16 if residual_bits == 16 else jnp.int32
    return QTensor(values=jnp.clip(acc, lo, hi).astype(dtype),
                   exponent=out_exponent, bits=residual_bits)


def resident_values(w: QTensor) -> jnp.ndarray:
    """In-jit float view of a stored-integer leaf, fusion-isolated.

    Unpacks the nibble/int8 grid and applies the power-of-2 de-scale —
    both exact, so the VALUES equal the plan-time dequantisation bit for
    bit — behind an ``optimization_barrier`` that keeps the quantiser ops
    out of the model's fusion regions (the PR-2 lesson).  Note the
    whole-program caveat: merely compiling quantiser ops into the same
    XLA module can re-tile unrelated reductions (LayerNorm/softmax) on
    CPU, so the runtime Engine's bit-identity contract additionally runs
    the unpack as its own executable (``Engine.live_params``); this
    in-jit path serves direct model calls on packed trees, where
    value-exactness (not cross-program bit-identity) is the contract.
    """
    return jax.lax.optimization_barrier(w.dequantize())


def qt_einsum(eq: str, x: jnp.ndarray, w: QTensor) -> jnp.ndarray:
    """Einsum against a *stored-integer* QTensor weight (integer-resident
    linear layers — the Engine's lut/pallas weight path).

    The weight bytes the jitted program closes over stay int8 /
    nibble-packed int4; the float view is materialised per call by
    :func:`resident_values` (exact unpack + po2 de-scale, fusion-isolated),
    so logits are **bit-identical** to the dequantise-first float-matmul
    path while storage is dtype-true end to end.

    Integer activations (a QTensor ``x``) are the full-integer pipeline:
    route those through ``kernels.ops.int8_matmul`` (the Pallas
    int8 x int8 -> int32 kernel over the same stored operands) or
    :func:`qmatmul`; this helper is the float-activation contract.
    """
    if isinstance(x, QTensor):
        raise TypeError("qt_einsum is the float-activation path; integer "
                        "activations go through kernels.ops.int8_matmul / "
                        "quant.qmatmul on the same stored operands")
    return jnp.einsum(eq, x, resident_values(w))


def dequantize_tree(tree: Pytree) -> Pytree:
    """Replace every QTensor leaf with its float32 dequantisation."""
    return jax.tree.map(
        lambda leaf: leaf.dequantize() if isinstance(leaf, QTensor) else leaf,
        tree, is_leaf=lambda leaf: isinstance(leaf, QTensor))


def quantize_tree(params: Pytree, *, weight_exponent: int = 6,
                  bits: int = 8, skip_norm_scales: bool = True,
                  rounding: str = "nearest") -> Pytree:
    """PTQ a parameter pytree with one global weight exponent (Table V row).

    LayerNorm/RMSNorm scale+shift vectors stay float (paper §IV) — detected
    as rank<=1 leaves when ``skip_norm_scales``.  Whole-model PTQ rounds to
    nearest (half-LSB offset before the eq-9 floor): the bare floor's
    correlated -LSB/2 bias visibly degrades LM logit ranks at the Table V
    exponents; pass ``rounding="floor"`` for the bit-exact paper cast.
    """
    def one(leaf):
        if not isinstance(leaf, jnp.ndarray) or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if skip_norm_scales and leaf.ndim <= 1:
            return leaf
        return quantize_po2(leaf, weight_exponent, bits=bits, rounding=rounding)

    return jax.tree.map(one, params)


def tree_quantized_bytes(tree: Pytree) -> tuple[int, int]:
    """(quantised_bytes, float_bytes) of a (partially) quantised tree.

    ``quantised_bytes`` is the TRUE packed storage count — nibble-packed
    bytes for ``bits<=4`` leaves plus any per-channel exponent bytes —
    i.e. the integer image a device would actually flash, not a
    dtype-derived fiction.
    """
    qb = fb = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            qb += leaf.stored_bytes
        elif isinstance(leaf, jnp.ndarray):
            fb += leaf.size * leaf.dtype.itemsize
    return qb, fb
