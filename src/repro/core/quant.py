"""Power-of-2 post-training static quantisation (paper §IV, eq 9, Table V).

    W_int = floor(W_float * 2^y), stored INT8, dequantised by bit shift.

Design points carried over from the paper:
  * scale factors are powers of two so (de)quantisation is a shift;
  * weights and inputs get *separate* exponents (Table V: 2^6 vs 2^5);
  * intermediate results of int matmuls accumulate wider (paper: INT16
    residuals; on TPU the MXU gives int32 accumulation for free, and we
    optionally clip back to int16 to reproduce the paper's storage type);
  * SoftMax and LayerNorm stay in float in the faithful path (§IV cites
    [12]: quantising them is "quite taxing on accuracy").

Beyond-paper (flagged, see DESIGN.md §5): per-channel exponents, int8
quantised Adam moments, int8 error-feedback gradient compression — the same
eq-9 primitive applied at other points of the stack.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

INT8_MIN, INT8_MAX = -128, 127
INT16_MIN, INT16_MAX = -(2**15), 2**15 - 1


def int_range(bits: int) -> tuple[int, int]:
    """The two's-complement range of a ``bits``-wide signed integer."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def storage_dtype(bits: int):
    """Narrowest container dtype for ``bits``-wide values.

    ``bits<=4`` values are *stored* nibble-packed (two per uint8 byte, see
    :func:`pack_po2`); their element dtype before packing is int8.
    """
    return jnp.int8 if bits <= 8 else jnp.int16


# ---------------------------------------------------------------------------
# The packed-int codec.  ONE implementation shared by Engine weights
# (integer-resident QTensors), QAT export artifacts (qat/export.py),
# compressed gradient payloads (dist/compress.py) and checkpoints.
# ---------------------------------------------------------------------------

def packed_length(n: int, bits: int) -> int:
    """Stored bytes for ``n`` values at ``bits`` width (nibble packing)."""
    return (n + 1) // 2 if bits <= 4 else n


def pack_po2(values: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack ``bits<=4`` two's-complement values, two nibbles per byte.

    ``values`` is any int array whose elements fit the ``bits``-wide range;
    the result is a flat uint8 array of ``ceil(n/2)`` bytes (low nibble =
    even index).  Odd lengths pad the final high nibble with zero; empty
    tensors pack to an empty byte string.  Exact inverse: :func:`unpack_po2`
    with the original shape — integers in, integers out, no float detour.
    """
    assert 1 <= bits <= 4, f"pack_po2 is the sub-byte codec (bits={bits})"
    flat = values.reshape(-1).astype(jnp.uint8)        # two's-complement wrap
    if flat.size % 2:
        flat = jnp.concatenate([flat, jnp.zeros((1,), jnp.uint8)])
    pairs = flat.reshape(-1, 2)
    return ((pairs[:, 0] & 0xF) | ((pairs[:, 1] & 0xF) << 4)).astype(jnp.uint8)


def unpack_po2(packed: jnp.ndarray, bits: int, shape) -> jnp.ndarray:
    """Inverse of :func:`pack_po2`: nibble-packed bytes -> int8 ``shape``.

    Sign-extends each 4-bit two's-complement nibble ((v ^ 8) - 8), so the
    round-trip is exact for every value in the ``bits``-wide range.
    """
    assert 1 <= bits <= 4, f"unpack_po2 is the sub-byte codec (bits={bits})"
    n = int(np.prod(shape, dtype=np.int64))
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    flat = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
    return ((flat.astype(jnp.int8) ^ 8) - 8).reshape(shape)


def pack_payload(values: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Storage form of an int tensor: nibble-packed for ``bits<=4``, the
    narrowest int dtype otherwise (the codec entry point non-QTensor
    callers — dist/compress payloads, export writers — share)."""
    if bits <= 4:
        return pack_po2(values, bits)
    return values.astype(storage_dtype(bits))


def unpack_payload(payload: jnp.ndarray, bits: int, shape) -> jnp.ndarray:
    """Inverse of :func:`pack_payload` (identity above 4 bits)."""
    if bits <= 4:
        return unpack_po2(payload, bits, shape)
    return payload.reshape(shape)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """An eq-9 quantised tensor: int values + static power-of-2 exponent.

    Storage is dtype-true (the bytes a 64 kB device would hold): int8 for
    ``4 < bits <= 8``, int16 above, and nibble-packed uint8 (two values
    per byte, :func:`pack_po2`) for ``bits <= 4``.  When packed,
    ``logical_shape`` carries the pre-pack shape and ``values`` is the
    flat byte image; :meth:`int_values` restores the int8 grid (inside
    jit too — unpacking is pure bit arithmetic).
    """

    values: jnp.ndarray               # int8 / int16, or uint8 nibble-packed
    exponent: int = dataclasses.field(metadata=dict(static=True))
    axis_exponents: jnp.ndarray | None = None    # per-channel (beyond-paper)
    bits: int = dataclasses.field(default=8, metadata=dict(static=True))
    logical_shape: tuple | None = dataclasses.field(
        default=None, metadata=dict(static=True))    # set iff nibble-packed

    @classmethod
    def store(cls, q: jnp.ndarray, exponent: int, *, bits: int = 8,
              axis_exponents: jnp.ndarray | None = None) -> "QTensor":
        """Build a dtype-true QTensor from an (already clipped) int grid."""
        qi = q.astype(storage_dtype(bits))     # signed cast BEFORE nibble wrap
        if bits <= 4:
            return cls(values=pack_po2(qi, bits), exponent=exponent,
                       axis_exponents=axis_exponents, bits=bits,
                       logical_shape=tuple(qi.shape))
        return cls(values=qi, exponent=exponent,
                   axis_exponents=axis_exponents, bits=bits)

    @property
    def packed(self) -> bool:
        return self.logical_shape is not None

    @property
    def shape(self):
        return self.logical_shape if self.packed else self.values.shape

    @property
    def stored_bytes(self) -> int:
        """True packed storage bytes (values + per-channel exponents)."""
        b = self.values.size * self.values.dtype.itemsize
        if self.axis_exponents is not None:
            b += self.axis_exponents.size * self.axis_exponents.dtype.itemsize
        return b

    def int_values(self) -> jnp.ndarray:
        """The integer grid at its logical shape (unpacks when packed)."""
        if self.packed:
            return unpack_po2(self.values, self.bits, self.logical_shape)
        return self.values

    def dequantize(self) -> jnp.ndarray:
        scale = jnp.float32(2.0 ** (-self.exponent))
        out = self.int_values().astype(jnp.float32) * scale
        if self.axis_exponents is not None:
            out = out * jnp.exp2(-self.axis_exponents.astype(jnp.float32))
        return out


def quantize_po2(w: jnp.ndarray, exponent: int, *, bits: int = 8,
                 stochastic_key: jax.Array | None = None,
                 rounding: str = "floor") -> QTensor:
    """eq 9: floor(w * 2^y) with saturation to the ``bits``-wide int range.

    ``rounding="nearest"`` adds the half-LSB offset before the floor (an
    adder in front of the truncating shift in hardware terms): floor's
    systematic -LSB/2 bias is correlated across every weight and measurably
    shifts whole-model logits; the offset removes it at zero ROM cost.

    Storage is the narrowest dtype for ``bits`` (int8 up to 8 bits,
    nibble-packed below 5 — no silent int16 widening), and saturation
    clips at the true ``bits``-wide edges (e.g. [-8, 7] at 4 bits).
    """
    lo, hi = int_range(bits)
    scaled = w.astype(jnp.float32) * (2.0 ** exponent)
    if rounding not in ("floor", "nearest"):
        raise ValueError(f"unknown rounding {rounding!r}")
    if stochastic_key is not None:  # beyond-paper: stochastic rounding option
        noise = jax.random.uniform(stochastic_key, w.shape)
        q = jnp.floor(scaled + noise)
    elif rounding == "nearest":
        q = jnp.floor(scaled + 0.5)
    else:
        q = jnp.floor(scaled)
    return QTensor.store(jnp.clip(q, lo, hi), exponent, bits=bits)


def choose_exponent(w: jnp.ndarray, *, bits: int = 8) -> int:
    """Largest y such that floor(max|w| * 2^y) does not saturate.

    The paper picks y by accuracy sweep (Table V); this is the analytic
    no-overflow bound used as the sweep's starting point.
    """
    import numpy as np

    maxabs = float(jnp.max(jnp.abs(w)))
    if maxabs == 0.0:
        return bits - 1
    return int(np.floor(np.log2((2 ** (bits - 1) - 1) / maxabs)))


def qmatmul(x: QTensor, w: QTensor, *, out_exponent: int | None = None,
            residual_bits: int = 16) -> QTensor:
    """Integer matmul with int32 accumulation and shift rescale.

    C_int32 = X_int8 @ W_int8 has exponent (x.e + w.e).  The result is
    shifted to ``out_exponent`` and clipped to the residual width (paper:
    INT16 intermediates).
    """
    xv, wv = x.int_values(), w.int_values()
    acc = jax.lax.dot_general(
        xv, wv,
        dimension_numbers=(((xv.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_exp = x.exponent + w.exponent
    out_exponent = acc_exp if out_exponent is None else out_exponent
    shift = acc_exp - out_exponent
    acc = jnp.where(shift >= 0, acc >> shift, acc << (-shift)) if isinstance(shift, jnp.ndarray) \
        else (acc >> shift if shift >= 0 else acc << (-shift))
    lo, hi = (INT16_MIN, INT16_MAX) if residual_bits == 16 else (-(2**31), 2**31 - 1)
    dtype = jnp.int16 if residual_bits == 16 else jnp.int32
    return QTensor(values=jnp.clip(acc, lo, hi).astype(dtype),
                   exponent=out_exponent, bits=residual_bits)


def resident_values(w: QTensor) -> jnp.ndarray:
    """In-jit float view of a stored-integer leaf, fusion-isolated.

    Unpacks the nibble/int8 grid and applies the power-of-2 de-scale —
    both exact, so the VALUES equal the plan-time dequantisation bit for
    bit — behind an ``optimization_barrier`` that keeps the quantiser ops
    out of the model's fusion regions (the PR-2 lesson).  Note the
    whole-program caveat: merely compiling quantiser ops into the same
    XLA module can re-tile unrelated reductions (LayerNorm/softmax) on
    CPU, so the runtime Engine's bit-identity contract additionally runs
    the unpack as its own executable (``Engine.live_params``); this
    in-jit path serves direct model calls on packed trees, where
    value-exactness (not cross-program bit-identity) is the contract.
    """
    return jax.lax.optimization_barrier(w.dequantize())


def qt_einsum(eq: str, x: jnp.ndarray, w: QTensor) -> jnp.ndarray:
    """Einsum against a *stored-integer* QTensor weight (integer-resident
    linear layers — the Engine's lut/pallas weight path).

    The weight bytes the jitted program closes over stay int8 /
    nibble-packed int4; the float view is materialised per call by
    :func:`resident_values` (exact unpack + po2 de-scale, fusion-isolated),
    so logits are **bit-identical** to the dequantise-first float-matmul
    path while storage is dtype-true end to end.

    Integer activations (a QTensor ``x``) are the full-integer pipeline:
    route those through ``kernels.ops.int8_matmul`` (the Pallas
    int8 x int8 -> int32 kernel over the same stored operands) or
    :func:`qmatmul`; this helper is the float-activation contract.
    """
    if isinstance(x, QTensor):
        raise TypeError("qt_einsum is the float-activation path; integer "
                        "activations go through kernels.ops.int8_matmul / "
                        "quant.qmatmul on the same stored operands")
    return jnp.einsum(eq, x, resident_values(w))


# ---------------------------------------------------------------------------
# Full-integer execution: eq-9 activation quantiser + integer-executing
# einsum over the STORED payload (no float weight view, no unpack stage).
# The function names below are load-bearing: analysis.residency whitelists
# int->float casts by trace-time frame (`int_container` / `requant` /
# `gather_descale`), and perf.cost prices their ops as the `requant` class.
# ---------------------------------------------------------------------------

# f32 holds every integer up to 2^24 exactly; while K * 2^(xbits-1) *
# 2^(wbits-1) stays under this, an f32 GEMM over integer grids is
# bit-equal to int32 accumulation (measured ~1.7x faster than XLA:CPU's
# int8 dot_general at KWT shapes — the win the lut backend banks on).
_F32_EXACT = 1 << 24

# Below this many MACs a contraction is dispatch-dominated on XLA:CPU
# (an Eigen dot thunk + its weight-convert thunk cost more than the math);
# int_exec_einsum emits a fusable multiply-reduce instead.
_SMALL_MACS = 8192


def matmul_unrolled(xq: jnp.ndarray, wi: jnp.ndarray, k: int) -> jnp.ndarray:
    """K-loop of a trivial contraction unrolled into elementwise
    multiply-adds (the named frame lets repro.perf price the chain as
    matmul MACs rather than loose elementwise ops)."""
    acc = xq[..., 0:1] * wi[0]
    for i in range(1, k):
        acc = acc + xq[..., i:i + 1] * wi[i]
    return acc


def quantize_act(x: jnp.ndarray, exponent: int, *, bits: int = 8
                 ) -> jnp.ndarray:
    """eq 9 applied to a linear-layer input: the jitted per-layer
    activation quantiser of the integer-executing pipeline.

    Same semantics as the PTQ/QAT weight cast (``quantize_po2`` /
    ``recipe.po2_fake_quant`` with nearest rounding): scale by the
    power-of-2 input exponent (Table V: 2^5), floor with the half-LSB
    offset, saturate at the ``bits``-wide edges.  Returns the integer
    GRID in an f32 container (values in [lo, hi], exactly representable)
    so the downstream matmul runs exact integer math without an
    int->float cast in the plan.
    """
    lo, hi = int_range(bits)
    q = jnp.floor(x.astype(jnp.float32) * jnp.float32(2.0 ** exponent) + 0.5)
    return jnp.clip(q, lo, hi)


def int_container(w: QTensor) -> jnp.ndarray:
    """The stored integer grid in an f32 container — value-preserving
    (every ``bits``-wide integer is exact in f32), NOT a dequantisation:
    no scale is applied, the values stay on the integer lattice.  Named
    so the residency pass can tell this container widening apart from a
    float weight view."""
    return w.int_values().astype(jnp.float32)


def requant(acc: jnp.ndarray, x_exp: int, w_exp: int,
            axis_exponents: jnp.ndarray | None = None) -> jnp.ndarray:
    """Power-of-2 requantisation epilogue of the integer matmul: descale
    the accumulator by 2^-(x_exp+w_exp), then the per-output-channel
    refinements.  All multiplications are by powers of two — exact in
    f32 — so jnp and Pallas realisations produce bit-identical floats."""
    if jnp.issubdtype(acc.dtype, jnp.integer):
        acc = acc.astype(jnp.float32)
    out = acc * jnp.float32(2.0 ** (-(x_exp + w_exp)))
    if axis_exponents is not None:
        out = out * jnp.exp2(-axis_exponents.astype(jnp.float32))
    return out


def int_exec_supported(w, eq: str) -> bool:
    """Can ``int_exec_einsum`` run ``eq`` against ``w`` integer-only?

    Supported: rank-2 weights contracted on the activation's last axis,
    weight-first (``bsd,df->bsf``-family) or weight-last (the tied-
    embedding head ``...d,vd->...v``).  Per-channel ``axis_exponents``
    live on the weight's LAST axis, so the weight-last layout puts them
    on the contraction axis where they cannot fold into a post-matmul
    epilogue — those fall back to the float-view path (documented LM
    tied-head exception).
    """
    if not isinstance(w, QTensor) or len(w.shape) != 2:
        return False
    lhs, rhs = eq.split("->")[0].split(",")
    if len(rhs) != 2:
        return False
    if rhs[0] == lhs[-1]:                 # weight-first: per-channel
        return True                       # exps fold into the epilogue
    if rhs[1] == lhs[-1]:                 # weight-last (tied head)
        return w.axis_exponents is None
    return False


def int_exec_einsum(eq: str, x: jnp.ndarray, w: QTensor, *,
                    x_exp: int, x_bits: int = 8, residual_bits: int = 16,
                    use_kernel: bool = False, interpret: bool = True
                    ) -> jnp.ndarray:
    """Integer-executing linear layer: quantise the activation (eq 9),
    multiply against the STORED int8 / nibble-packed int4 payload, clip
    to the paper's INT16 residual, requantise.  No ``dequantize_tree``
    stage, no float weight view — the only float-producing op in the
    plan is the exact po2 :func:`requant` epilogue.

    ``use_kernel`` routes the matmul through the Pallas int8 x int8 ->
    int32 kernel (``kernels.ops.int8_matmul``) — the compiled-Mosaic
    path.  In interpret mode the jnp realisation below IS the kernel's
    reference semantics (same integer accumulation, same int16 clip,
    same epilogue order), bit-identical by construction and without the
    kernel's (8,128)/(128,128) padding round-trip per call.
    """
    lhs, rhs = eq.split("->")[0].split(",")
    transpose_w = rhs[0] != lhs[-1]       # weight-last (tied head) layout
    k = int(x.shape[-1])
    xq = quantize_act(x, x_exp, bits=x_bits)
    if use_kernel and not interpret and not transpose_w:
        from repro.kernels import ops as _kops
        lead = x.shape[:-1]
        out2 = _kops.int8_matmul(xq.reshape(-1, k).astype(jnp.int8), w,
                                 x_exp=x_exp,
                                 residual_bits=residual_bits,
                                 interpret=interpret)
        return out2.reshape(*lead, out2.shape[-1])
    # contract the LAST activation axis in place — no flatten/unflatten
    # round-trip, so XLA keeps float-plan layouts downstream (a 2D
    # reshape here costs two copy fusions per linear and forces a
    # strided layout on the attention batch dots; measured ~3x on the
    # scores matmul).  Bit-identical: each output element is the same
    # ordered K-reduction either way.
    dims = (((xq.ndim - 1,), (0,)), ((), ()))
    macs = xq.size // k * k * int(w.shape[0 if transpose_w else 1])
    if k * 2 ** (x_bits - 1) * 2 ** (w.bits - 1) <= _F32_EXACT:
        # exact integer math in f32 containers (see _F32_EXACT)
        wi = int_container(w)
        if transpose_w:
            wi = wi.T
        if macs <= _SMALL_MACS:
            # trivial contraction (the classifier head): unroll the K-loop
            # into elementwise multiply-adds so XLA fuses the s8->f32
            # container widening, the products, the int16 clip and the
            # requant epilogue into the neighbouring fusions — zero
            # standalone thunks, vs a weight-convert thunk plus a dot
            # thunk (or a multiply fusion plus a reduce thunk for a
            # sum-over-axis form).  Every product and partial sum is an
            # exact integer under _F32_EXACT, so any summation order
            # gives the same value — bit-identical to the dot.
            acc = matmul_unrolled(xq, wi, k)
        else:
            acc = jax.lax.dot_general(xq, wi, dims,
                                      preferred_element_type=jnp.float32)
    else:
        # contraction too long for the f32 mantissa: true int32 path
        wl = w.int_values()
        if transpose_w:
            wl = wl.T
        acc = jax.lax.dot_general(xq.astype(jnp.int32), wl, dims,
                                  preferred_element_type=jnp.int32)
    if residual_bits == 16:
        acc = jnp.clip(acc, INT16_MIN, INT16_MAX)
    axis = None if transpose_w else w.axis_exponents
    return requant(acc, x_exp, w.exponent, axis)


def int_exec_qkv(x: jnp.ndarray, ws, *, x_exp: int, x_bits: int = 8,
                 residual_bits: int = 16):
    """Fused Q/K/V integer projection: ONE int8 x int8 dot over the
    three stored payloads concatenated on the output axis, with each
    leaf's scalar-exponent delta folded into the per-column requant
    epilogue.  Bitwise equal to three separate :func:`int_exec_einsum`
    calls — an f32 dot's K-reduction is per-column independent, and the
    po2 column scale 2^-(x+e0+delta) == 2^-(x+e_leaf)·2^-axis_leaf
    exactly — at a third of the dot/convert thunk dispatches.

    Returns the per-leaf outputs (split back at the leaf widths).
    """
    k = int(x.shape[-1])
    xq = quantize_act(x, x_exp, bits=x_bits)
    dims = (((xq.ndim - 1,), (0,)), ((), ()))
    wide = max(w.bits for w in ws)
    if k * 2 ** (x_bits - 1) * 2 ** (wide - 1) <= _F32_EXACT:
        wi = jnp.concatenate([int_container(w) for w in ws], axis=-1)
        acc = jax.lax.dot_general(xq, wi, dims,
                                  preferred_element_type=jnp.float32)
    else:
        wl = jnp.concatenate([w.int_values() for w in ws], axis=-1)
        acc = jax.lax.dot_general(xq.astype(jnp.int32), wl, dims,
                                  preferred_element_type=jnp.int32)
    if residual_bits == 16:
        acc = jnp.clip(acc, INT16_MIN, INT16_MAX)
    e0 = ws[0].exponent
    if all(w.exponent == e0 and w.axis_exponents is None for w in ws):
        axis = None
    else:
        cols = []
        for w in ws:
            delta = jnp.full((w.shape[-1],), w.exponent - e0, jnp.float32)
            if w.axis_exponents is not None:
                delta = delta + w.axis_exponents.astype(jnp.float32)
            cols.append(delta)
        axis = jnp.concatenate(cols)
    out = requant(acc, x_exp, e0, axis)
    splits = np.cumsum([w.shape[-1] for w in ws])[:-1].tolist()
    return jnp.split(out, splits, axis=-1)


def gather_descale(w: QTensor, idx: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup against the stored payload: gather integer ROWS,
    then descale only what was looked up.  The full table never
    materialises as float — the LM embed family's integer-executing
    replacement for dequantise-first."""
    rows = jnp.take(w.int_values(), idx, axis=0)
    out = rows.astype(jnp.float32) * jnp.float32(2.0 ** (-w.exponent))
    if w.axis_exponents is not None:
        out = out * jnp.exp2(-w.axis_exponents.astype(jnp.float32))
    return out


def dequantize_tree(tree: Pytree) -> Pytree:
    """Replace every QTensor leaf with its float32 dequantisation."""
    return jax.tree.map(
        lambda leaf: leaf.dequantize() if isinstance(leaf, QTensor) else leaf,
        tree, is_leaf=lambda leaf: isinstance(leaf, QTensor))


def quantize_tree(params: Pytree, *, weight_exponent: int = 6,
                  bits: int = 8, skip_norm_scales: bool = True,
                  rounding: str = "nearest") -> Pytree:
    """PTQ a parameter pytree with one global weight exponent (Table V row).

    LayerNorm/RMSNorm scale+shift vectors stay float (paper §IV) — detected
    as rank<=1 leaves when ``skip_norm_scales``.  Whole-model PTQ rounds to
    nearest (half-LSB offset before the eq-9 floor): the bare floor's
    correlated -LSB/2 bias visibly degrades LM logit ranks at the Table V
    exponents; pass ``rounding="floor"`` for the bit-exact paper cast.
    """
    def one(leaf):
        if not isinstance(leaf, jnp.ndarray) or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if skip_norm_scales and leaf.ndim <= 1:
            return leaf
        return quantize_po2(leaf, weight_exponent, bits=bits, rounding=rounding)

    return jax.tree.map(one, params)


def tree_quantized_bytes(tree: Pytree) -> tuple[int, int]:
    """(quantised_bytes, float_bytes) of a (partially) quantised tree.

    ``quantised_bytes`` is the TRUE packed storage count — nibble-packed
    bytes for ``bits<=4`` leaves plus any per-channel exponent bytes —
    i.e. the integer image a device would actually flash, not a
    dtype-derived fiction.
    """
    qb = fb = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            qb += leaf.stored_bytes
        elif isinstance(leaf, jnp.ndarray):
            fb += leaf.size * leaf.dtype.itemsize
    return qb, fb
