"""Power-of-2 post-training static quantisation (paper §IV, eq 9, Table V).

    W_int = floor(W_float * 2^y), stored INT8, dequantised by bit shift.

Design points carried over from the paper:
  * scale factors are powers of two so (de)quantisation is a shift;
  * weights and inputs get *separate* exponents (Table V: 2^6 vs 2^5);
  * intermediate results of int matmuls accumulate wider (paper: INT16
    residuals; on TPU the MXU gives int32 accumulation for free, and we
    optionally clip back to int16 to reproduce the paper's storage type);
  * SoftMax and LayerNorm stay in float in the faithful path (§IV cites
    [12]: quantising them is "quite taxing on accuracy").

Beyond-paper (flagged, see DESIGN.md §5): per-channel exponents, int8
quantised Adam moments, int8 error-feedback gradient compression — the same
eq-9 primitive applied at other points of the stack.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

INT8_MIN, INT8_MAX = -128, 127
INT16_MIN, INT16_MAX = -(2**15), 2**15 - 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QTensor:
    """An eq-9 quantised tensor: int values + static power-of-2 exponent."""

    values: jnp.ndarray                                   # int8 / int16
    exponent: int = dataclasses.field(metadata=dict(static=True))
    axis_exponents: jnp.ndarray | None = None             # per-channel (beyond-paper)

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self) -> jnp.ndarray:
        scale = jnp.float32(2.0 ** (-self.exponent))
        out = self.values.astype(jnp.float32) * scale
        if self.axis_exponents is not None:
            out = out * jnp.exp2(-self.axis_exponents.astype(jnp.float32))
        return out


def quantize_po2(w: jnp.ndarray, exponent: int, *, bits: int = 8,
                 stochastic_key: jax.Array | None = None,
                 rounding: str = "floor") -> QTensor:
    """eq 9: floor(w * 2^y) with saturation to the int range.

    ``rounding="nearest"`` adds the half-LSB offset before the floor (an
    adder in front of the truncating shift in hardware terms): floor's
    systematic -LSB/2 bias is correlated across every weight and measurably
    shifts whole-model logits; the offset removes it at zero ROM cost.
    """
    lo, hi = (INT8_MIN, INT8_MAX) if bits == 8 else (INT16_MIN, INT16_MAX)
    scaled = w.astype(jnp.float32) * (2.0 ** exponent)
    if rounding not in ("floor", "nearest"):
        raise ValueError(f"unknown rounding {rounding!r}")
    if stochastic_key is not None:  # beyond-paper: stochastic rounding option
        noise = jax.random.uniform(stochastic_key, w.shape)
        q = jnp.floor(scaled + noise)
    elif rounding == "nearest":
        q = jnp.floor(scaled + 0.5)
    else:
        q = jnp.floor(scaled)
    dtype = jnp.int8 if bits == 8 else jnp.int16
    return QTensor(values=jnp.clip(q, lo, hi).astype(dtype), exponent=exponent)


def choose_exponent(w: jnp.ndarray, *, bits: int = 8) -> int:
    """Largest y such that floor(max|w| * 2^y) does not saturate.

    The paper picks y by accuracy sweep (Table V); this is the analytic
    no-overflow bound used as the sweep's starting point.
    """
    import numpy as np

    maxabs = float(jnp.max(jnp.abs(w)))
    if maxabs == 0.0:
        return bits - 1
    return int(np.floor(np.log2((2 ** (bits - 1) - 1) / maxabs)))


def qmatmul(x: QTensor, w: QTensor, *, out_exponent: int | None = None,
            residual_bits: int = 16) -> QTensor:
    """Integer matmul with int32 accumulation and shift rescale.

    C_int32 = X_int8 @ W_int8 has exponent (x.e + w.e).  The result is
    shifted to ``out_exponent`` and clipped to the residual width (paper:
    INT16 intermediates).
    """
    acc = jax.lax.dot_general(
        x.values, w.values,
        dimension_numbers=(((x.values.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_exp = x.exponent + w.exponent
    out_exponent = acc_exp if out_exponent is None else out_exponent
    shift = acc_exp - out_exponent
    acc = jnp.where(shift >= 0, acc >> shift, acc << (-shift)) if isinstance(shift, jnp.ndarray) \
        else (acc >> shift if shift >= 0 else acc << (-shift))
    lo, hi = (INT16_MIN, INT16_MAX) if residual_bits == 16 else (-(2**31), 2**31 - 1)
    dtype = jnp.int16 if residual_bits == 16 else jnp.int32
    return QTensor(values=jnp.clip(acc, lo, hi).astype(dtype), exponent=out_exponent)


def dequantize_tree(tree: Pytree) -> Pytree:
    """Replace every QTensor leaf with its float32 dequantisation."""
    return jax.tree.map(
        lambda leaf: leaf.dequantize() if isinstance(leaf, QTensor) else leaf,
        tree, is_leaf=lambda leaf: isinstance(leaf, QTensor))


def quantize_tree(params: Pytree, *, weight_exponent: int = 6,
                  bits: int = 8, skip_norm_scales: bool = True,
                  rounding: str = "nearest") -> Pytree:
    """PTQ a parameter pytree with one global weight exponent (Table V row).

    LayerNorm/RMSNorm scale+shift vectors stay float (paper §IV) — detected
    as rank<=1 leaves when ``skip_norm_scales``.  Whole-model PTQ rounds to
    nearest (half-LSB offset before the eq-9 floor): the bare floor's
    correlated -LSB/2 bias visibly degrades LM logit ranks at the Table V
    exponents; pass ``rounding="floor"`` for the bit-exact paper cast.
    """
    def one(leaf):
        if not isinstance(leaf, jnp.ndarray) or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if skip_norm_scales and leaf.ndim <= 1:
            return leaf
        return quantize_po2(leaf, weight_exponent, bits=bits, rounding=rounding)

    return jax.tree.map(one, params)


def tree_quantized_bytes(tree: Pytree) -> tuple[int, int]:
    """(quantised_bytes, float_bytes) of a (partially) quantised tree."""
    qb = fb = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            qb += leaf.values.size * leaf.values.dtype.itemsize
        elif isinstance(leaf, jnp.ndarray):
            fb += leaf.size * leaf.dtype.itemsize
    return qb, fb
