"""QuantRecipe: the paper's PTQ pipeline (§IV, eq 9, Table V) as one value.

A recipe is everything ``runtime.compile_model`` needs to turn float
parameters into the deployed numeric form: weight/input exponents, the
rounding rule for the eq-9 cast, optional per-channel exponent refinement,
and the residual (intermediate) width.  It subsumes the old
``launch.serve.quantize_params`` helper — launchers no longer hand-roll
``quantize_tree`` + ``dequantize_tree`` call pairs.

It is also the single source of truth for quantiser *semantics*: the QAT
fake-quant primitives (``repro.qat.fakequant``) call the same
:func:`po2_fake_quant` this module uses for PTQ, so the values a QAT
forward pass trains on are bit-identical to the values the deployed
engine runs — the export-parity contract in ``repro.qat.export``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant

Pytree = Any


def po2_fake_quant(w: jnp.ndarray, weight_exponent, *, bits: int = 8,
                   rounding: str = "nearest", per_channel: bool = False):
    """The eq-9 cast in float: quantise-dequantise without the int8 store.

    Returns ``(fq, q, extra, unsat)``:
      * ``fq`` — the dequantised float values, bit-identical to
        ``QuantRecipe.quantize(...)`` -> ``dequantize`` (power-of-2 scales
        make every (de)scale multiplication exact in f32);
      * ``q`` — the clipped integer grid (f32 values in [lo, hi]; the
        exact values ``QuantRecipe.quantize`` casts to int8);
      * ``extra`` — the per-channel exponent refinements (int32, last-axis
        channels) or ``None`` on the scalar path;
      * ``unsat`` — bool mask of lanes whose cast did NOT saturate (the
        clipped-STE gradient gate used by ``repro.qat.fakequant``).

    ``weight_exponent`` may be a traced value (QAT exponent learning);
    ``jnp.exp2`` of an integral f32 is exact, so traced and static
    exponents produce identical values.
    """
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1) - 1
    wf = w.astype(jnp.float32)
    e = jnp.asarray(weight_exponent, jnp.float32)
    extra = None
    if per_channel and w.ndim >= 2:
        # Per-channel refinement: each output channel (last axis) shifts to
        # its own no-saturation bound — extra precision for small channels,
        # saturation-free casts for large ones, still power-of-2 shifts
        # only (zero multiplier cost; stored as QTensor.axis_exponents).
        axes = tuple(range(w.ndim - 1))
        maxabs = jnp.max(jnp.abs(wf), axis=axes)
        extra = jnp.floor(jnp.log2(hi / jnp.maximum(maxabs, 1e-30)))
        extra = jnp.clip(extra - e, -12, 12).astype(jnp.int32)
        scaled = wf * jnp.exp2(e + extra.astype(jnp.float32))
    else:
        scaled = wf * jnp.exp2(e)
    if rounding == "nearest":
        q = jnp.floor(scaled + 0.5)
    elif rounding == "floor":
        q = jnp.floor(scaled)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    unsat = jnp.logical_and(q >= lo, q <= hi)
    q = jnp.clip(q, lo, hi)
    # dequantise in the same order QTensor.dequantize uses (both exact)
    fq = q * jnp.exp2(-e)
    if extra is not None:
        fq = fq * jnp.exp2(-extra.astype(jnp.float32))
    return fq, q, extra, unsat


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """One deployment's quantisation policy (paper §IV + Table V).

    ``weight_exponent``/``input_exponent`` are the Table V power-of-2
    scales (best row: weights 2^6, inputs 2^5).  ``rounding`` selects the
    eq-9 cast: ``"nearest"`` adds the half-LSB offset (default — floor's
    correlated bias measurably shifts whole-model logits), ``"floor"``
    reproduces the paper's cast bit-exactly.  ``per_channel`` refines each
    output channel to its own no-saturation power-of-2 exponent
    (beyond-paper; stored as ``QTensor.axis_exponents``, shifts only).
    ``residual_bits=16`` is the paper's INT16 intermediate clip, consumed
    by the int8 matmul path (``kernels.ops.int8_matmul``).
    """

    weight_exponent: int = 6
    input_exponent: int = 5
    bits: int = 8
    residual_bits: int = 16
    rounding: str = "nearest"
    per_channel: bool = False
    skip_norm_scales: bool = True      # norms/biases stay float (paper §IV)

    @classmethod
    def from_config(cls, cfg, **overrides) -> "QuantRecipe":
        """Build from ``cfg.quant`` (configs.base.QuantConfig) or defaults.

        ``per_channel`` resolves registry-driven: an explicit
        ``cfg.quant.per_channel`` wins; otherwise LM-scale families default
        to per-channel refinement (the PR-3 follow-up — one global exponent
        wastes resolution across a 100k-row embedding), while ``kwt``
        configs keep the paper's scalar Table V recipe.
        """
        q = getattr(cfg, "quant", None)
        kw = {"per_channel": cfg.family != "kwt"}
        if q is not None:
            kw.update({"weight_exponent": q.weight_exponent,
                       "input_exponent": q.input_exponent,
                       "residual_bits": q.residual_bits,
                       "bits": getattr(q, "bits", 8)})
            if q.per_channel is not None:
                kw["per_channel"] = q.per_channel
        kw.update(overrides)
        return cls(**kw)

    def with_(self, **kw) -> "QuantRecipe":
        return dataclasses.replace(self, **kw)

    # -- serialisation (QAT export artifacts, BENCH_qat.json) --------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "QuantRecipe":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    # -- calibration --------------------------------------------------------

    def calibrated(self, params: Pytree) -> "QuantRecipe":
        """Recipe with the analytic no-saturation weight exponent for
        ``params`` (largest y with no quantised leaf clipping) — the
        concrete-value counterpart of the QAT exponent-learning loop."""
        exps = [quant.choose_exponent(leaf, bits=self.bits)
                for leaf in jax.tree.leaves(params) if self._quantizes(leaf)]
        if not exps:
            return self
        return self.with_(weight_exponent=int(min(exps)))

    # -- application -------------------------------------------------------

    def _quantizes(self, leaf) -> bool:
        """Leaf selection shared with the QAT fake-quant path: norms and
        biases (rank<=1) stay float per paper §IV."""
        if not isinstance(leaf, jnp.ndarray) or \
                not jnp.issubdtype(leaf.dtype, jnp.floating):
            return False
        return not (self.skip_norm_scales and leaf.ndim <= 1)

    def _quantize_leaf(self, w: jnp.ndarray) -> quant.QTensor:
        if not self.per_channel or w.ndim < 2:
            return quant.quantize_po2(w, self.weight_exponent, bits=self.bits,
                                      rounding=self.rounding)
        _, q, extra, _ = po2_fake_quant(
            w, self.weight_exponent, bits=self.bits, rounding=self.rounding,
            per_channel=True)
        # dtype-true storage through the shared codec (nibble-packed below
        # 5 bits); per-channel refinements are clipped to [-12, 12] so one
        # int8 per output channel stores them exactly.
        return quant.QTensor.store(q, self.weight_exponent, bits=self.bits,
                                   axis_exponents=extra.astype(jnp.int8))

    def fake_quant_leaf(self, w: jnp.ndarray, weight_exponent=None):
        """(fq, unsat) for one weight leaf — the QAT forward-pass values.
        ``weight_exponent`` (possibly traced) overrides the recipe field."""
        e = self.weight_exponent if weight_exponent is None else weight_exponent
        fq, _, _, unsat = po2_fake_quant(w, e, bits=self.bits,
                                         rounding=self.rounding,
                                         per_channel=self.per_channel and
                                         w.ndim >= 2)
        return fq, unsat

    def quantize(self, params: Pytree) -> Pytree:
        """params -> tree with QTensor leaves (norms/biases stay float)."""
        def one(leaf):
            if not self._quantizes(leaf):
                return leaf
            return self._quantize_leaf(leaf)

        return jax.tree.map(one, params)

    def apply(self, params: Pytree) -> Pytree:
        """PTQ round-trip: the float params the deployed engine actually
        runs (int8 values de-scaled by their power-of-2 shifts)."""
        return quant.dequantize_tree(self.quantize(params))

    def quantized_bytes(self, params: Pytree) -> tuple[int, int]:
        """(int bytes, residual float bytes) of the deployed tree."""
        return quant.tree_quantized_bytes(self.quantize(params))
