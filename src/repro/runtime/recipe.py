"""QuantRecipe: the paper's PTQ pipeline (§IV, eq 9, Table V) as one value.

A recipe is everything ``runtime.compile_model`` needs to turn float
parameters into the deployed numeric form: weight/input exponents, the
rounding rule for the eq-9 cast, optional per-channel exponent refinement,
and the residual (intermediate) width.  It subsumes the old
``launch.serve.quantize_params`` helper — launchers no longer hand-roll
``quantize_tree`` + ``dequantize_tree`` call pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant

Pytree = Any


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """One deployment's quantisation policy (paper §IV + Table V).

    ``weight_exponent``/``input_exponent`` are the Table V power-of-2
    scales (best row: weights 2^6, inputs 2^5).  ``rounding`` selects the
    eq-9 cast: ``"nearest"`` adds the half-LSB offset (default — floor's
    correlated bias measurably shifts whole-model logits), ``"floor"``
    reproduces the paper's cast bit-exactly.  ``per_channel`` refines each
    output channel to its own no-saturation power-of-2 exponent
    (beyond-paper; stored as ``QTensor.axis_exponents``, shifts only).
    ``residual_bits=16`` is the paper's INT16 intermediate clip, consumed
    by the int8 matmul path (``kernels.ops.int8_matmul``).
    """

    weight_exponent: int = 6
    input_exponent: int = 5
    bits: int = 8
    residual_bits: int = 16
    rounding: str = "nearest"
    per_channel: bool = False
    skip_norm_scales: bool = True      # norms/biases stay float (paper §IV)

    @classmethod
    def from_config(cls, cfg, **overrides) -> "QuantRecipe":
        """Build from ``cfg.quant`` (configs.base.QuantConfig) or defaults."""
        q = getattr(cfg, "quant", None)
        kw = {}
        if q is not None:
            kw = {"weight_exponent": q.weight_exponent,
                  "input_exponent": q.input_exponent,
                  "residual_bits": q.residual_bits}
        kw.update(overrides)
        return cls(**kw)

    def with_(self, **kw) -> "QuantRecipe":
        return dataclasses.replace(self, **kw)

    # -- application -------------------------------------------------------

    def _quantize_leaf(self, w: jnp.ndarray) -> quant.QTensor:
        if not self.per_channel or w.ndim < 2:
            return quant.quantize_po2(w, self.weight_exponent, bits=self.bits,
                                      rounding=self.rounding)
        # Per-channel refinement: each output channel (last axis) shifts to
        # its own no-saturation bound — extra precision for small channels,
        # saturation-free casts for large ones, still power-of-2 shifts
        # only (zero multiplier cost; stored as QTensor.axis_exponents).
        lo = -(2 ** (self.bits - 1))
        hi = 2 ** (self.bits - 1) - 1
        wf = w.astype(jnp.float32)
        axes = tuple(range(w.ndim - 1))
        maxabs = jnp.max(jnp.abs(wf), axis=axes)
        extra = jnp.floor(jnp.log2(hi / jnp.maximum(maxabs, 1e-30)))
        extra = jnp.clip(extra - self.weight_exponent, -12, 12).astype(jnp.int32)
        scaled = wf * jnp.exp2((self.weight_exponent + extra).astype(jnp.float32))
        if self.rounding == "nearest":
            q = jnp.floor(scaled + 0.5)
        elif self.rounding == "floor":
            q = jnp.floor(scaled)
        else:
            raise ValueError(f"unknown rounding {self.rounding!r}")
        dtype = jnp.int8 if self.bits == 8 else jnp.int16
        return quant.QTensor(values=jnp.clip(q, lo, hi).astype(dtype),
                             exponent=self.weight_exponent,
                             axis_exponents=extra)

    def quantize(self, params: Pytree) -> Pytree:
        """params -> tree with QTensor leaves (norms/biases stay float)."""
        def one(leaf):
            if not isinstance(leaf, jnp.ndarray) or \
                    not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            if self.skip_norm_scales and leaf.ndim <= 1:
                return leaf
            return self._quantize_leaf(leaf)

        return jax.tree.map(one, params)

    def apply(self, params: Pytree) -> Pytree:
        """PTQ round-trip: the float params the deployed engine actually
        runs (int8 values de-scaled by their power-of-2 shifts)."""
        return quant.dequantize_tree(self.quantize(params))

    def quantized_bytes(self, params: Pytree) -> tuple[int, int]:
        """(int bytes, residual float bytes) of the deployed tree."""
        return quant.tree_quantized_bytes(self.quantize(params))
