"""Engine: one compiled execution plan for one model + backend + recipe.

``compile_model(cfg, params, backend=..., recipe=...)`` is the single
entry point through which every launcher, example and benchmark selects
execution.  It resolves the backend (float / lut_float / lut / pallas),
applies the QuantRecipe PTQ when the backend calls for it, pins the
execution modes onto the config ONCE (including the Pallas
interpret-vs-Mosaic decision), and returns an ``Engine`` whose jitted
entry points all run that one plan:

    eng = runtime.compile_model(cfg, params, backend="lut")
    logits = eng.forward(mfcc)            # offline [B, F, T] -> [B, C]
    emb    = eng.embed_frames(frames)     # streaming building blocks
    logits = eng.encode_window(window)    #   (consumed by stream.engine)
    state, logits = eng.stream_step(state, chunk, fcfg)

LM families additionally expose ``init_decode_state`` / ``prefill`` /
``decode_step`` so ``launch/serve.py`` runs off the same object.

Contract (tests/test_runtime.py): for any backend, streaming logits are
bit-identical to that same engine's offline ``forward`` on the matching
audio window — the PR-2 float/LUT bit-identity guarantee restated at the
Engine level — and float/lut/pallas logits agree within the documented
PTQ + LUT-bin tolerance.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

import jax

from repro.core import lut as lutlib
from repro.core import quant
from repro.runtime.backends import Backend, get_backend
from repro.runtime.recipe import QuantRecipe
from repro.telemetry import taps as _taps
from repro.telemetry import trace as _trace

Pytree = Any


def _model_module(cfg):
    if cfg.family == "kwt":
        from repro.models import kwt
        return kwt
    from repro.launch import steps
    return steps.model_module(cfg)


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclasses.dataclass
class Engine:
    """A planned model: prepared params + pinned execution config.

    ``exec_cfg`` is the ONLY config that carries softmax_mode /
    act_approx / kernel_interpret different from the user's ``cfg`` —
    drivers that build their own fused jits (e.g. the streaming server's
    joint engine+detector hop) close over ``eng.exec_cfg`` and pass
    ``eng.live_params()`` (NOT ``eng.params``: integer-resident plans
    store packed QTensors there, and dequantising inside the driver's
    own XLA module would forfeit the bit-identity contract — see
    :meth:`live_params`), so execution policy still has a single source.
    """

    cfg: Any                        # the config compile_model was given
    exec_cfg: Any                   # cfg with the backend's modes pinned
    params: Pytree                  # PTQ-applied when the backend quantizes
    backend: Backend
    recipe: Optional[QuantRecipe]
    quantized_bytes: Optional[tuple] = None   # (int bytes, float bytes)
    taps: bool = False              # forward also returns quant-health aux
    int_exec: bool = False          # integer-executing plan: the model
    #                                 consumes the packed tree directly
    #                                 (no per-call unpack stage/span)

    def __post_init__(self):
        self._mod = _model_module(self.exec_cfg)
        cfg = self.exec_cfg
        self._forward = jax.jit(lambda p, x: self._mod.forward(p, x, cfg))
        self._embed = self._encode = self._prefill = self._decode = None
        self._stream_steps = {}
        self._taps_fn = None
        self._unpack = jax.jit(quant.dequantize_tree) \
            if self.int_resident and not self.int_exec else None
        # Fast dispatch for plans whose operand tree never changes between
        # calls (no per-call unpack): pre-flatten the params ONCE and jit a
        # wrapper over the flat leaves.  Per-call argument processing then
        # walks a flat tuple of plain arrays instead of re-flattening
        # registered-dataclass QTensor nodes in Python — measured ~15 us
        # per forward on the integer-executing kwt-tiny plan, with the
        # unflatten happening only at trace time (identical jaxpr/HLO, so
        # logits are bit-identical to the tree-operand executable).
        self._forward_flat = self._flat_leaves = None
        if self._unpack is None:
            leaves, treedef = jax.tree_util.tree_flatten(self.params)
            self._flat_leaves = tuple(leaves)
            self._forward_flat = jax.jit(
                lambda lv, x: self._mod.forward(
                    jax.tree_util.tree_unflatten(treedef, lv), x, cfg))
        if cfg.family == "kwt":
            self._embed = jax.jit(
                lambda p, fr: self._mod.embed_frames(p, fr, cfg))
            self._encode = jax.jit(
                lambda p, w: self._mod.encode_window(p, w, cfg))

    def live_params(self):
        """The float operand tree the model executables run on.

        Integer-EXECUTING plans (``int_exec``) have no float view at all:
        the model executables consume the packed QTensors directly
        (``quant.int_exec_einsum``), so this returns ``params`` as-is.

        Non-executing integer-resident plans store packed int8 /
        nibble-packed int4 QTensors in ``params``; the float view is
        materialised per call by a separate jitted unpack program — the
        software analogue of the device's shift-dequantiser stage (ROM
        bytes stay packed, the float image is a transient).  Keeping the
        unpack in its OWN executable is load-bearing for the bit-identity
        contract: when quantiser ops share the model's XLA module, CPU
        fusion re-tiles unrelated reductions (LayerNorm/softmax) and
        rounding becomes weight-producer-dependent; as a separate stage
        the model executable is byte-identical to the dequantise-first
        plan and receives bit-identical operand values (po2 de-scales
        are exact).
        """
        return self.params if self._unpack is None else \
            self._unpack(self.params)

    # -- inference entry points (all jitted, params passed as operands) ----

    def forward(self, x):
        """Offline forward: kwt mfcc [B,F,T] -> logits; LM tokens -> logits.

        With ``taps`` planned (``compile_model(..., taps=True)``) returns
        ``(logits, aux)`` where ``aux`` maps tap sites to quantisation-
        health scalars (telemetry.taps).  Logits always come from the SAME
        untapped executable either way — bit-identity by construction.
        """
        tr = _trace.active_tracer()
        if tr is None and not self.taps:
            if self._forward_flat is not None:
                return self._forward_flat(self._flat_leaves, x)
            return self._forward(self.live_params(), x)
        return self._forward_instrumented(tr, x)

    def _live_traced(self, tr):
        """Operand tree under tracing.  Plans with no unpack program —
        float params, or integer-EXECUTING packed params — emit no
        ``unpack`` span: there is no unpack stage to attribute (timing
        the identity ``live_params`` walk would charge tree-flatten
        noise to a stage the plan does not have)."""
        if self._unpack is None:
            return self.params
        with tr.span("unpack"):
            return jax.block_until_ready(self.live_params())

    def _forward_instrumented(self, tr, x):
        if tr is None:                         # taps only, no tracing
            lp = self.live_params()
            return self._forward(lp, x), self._run_taps(lp, x)
        # Spans measure device work: fence each stage with
        # block_until_ready (async dispatch is preserved when untraced).
        with tr.span("forward", {"backend": self.backend.name}):
            lp = self._live_traced(tr)
            with tr.span("encode"):
                # same executable selection as the untraced path: the flat
                # pre-flattened program when the operand tree is static
                # (lp IS self.params then), so the span times the serving
                # executable rather than compiling the tree-operand twin
                logits = jax.block_until_ready(
                    self._forward_flat(self._flat_leaves, x)
                    if self._forward_flat is not None
                    else self._forward(lp, x))
            if self.taps:
                with tr.span("taps"):
                    aux = jax.block_until_ready(self._run_taps(lp, x))
                return logits, aux
        return logits

    def _run_taps(self, lp, x):
        """The separate jitted aux program of a ``taps=True`` plan.

        Re-traces ``forward`` with the telemetry.taps collector active
        and returns ONLY the health statistics; served logits never come
        from this executable.  Keeping the tapped trace out of the
        serving program is load-bearing for the bit-identity criterion:
        extra aux outputs change what CPU XLA fuses, which re-tiles
        reductions and shifts logit rounding (same mechanism the
        separate unpack stage guards against — see ``live_params``).
        The cost — a second forward pass — is a diagnostic-mode cost.
        """
        if self._taps_fn is None:
            mod, cfg = self._mod, self.exec_cfg

            def aux_program(p, x):
                with _taps.collecting() as col:
                    logits = mod.forward(p, x, cfg)
                    _taps.tap_activation("logits", logits, cfg)
                return _taps.pack(col)

            self._taps_fn = jax.jit(aux_program)
        return self._taps_fn(lp, x)

    def embed_frames(self, frames):
        """[B, t, F] time-major frames -> [B, t, d] patch embeddings."""
        self._require_kwt("embed_frames")
        return self._embed(self.live_params(), frames)

    def encode_window(self, window):
        """Assembled [B, T, d] window -> logits [B, n_classes]."""
        self._require_kwt("encode_window")
        return self._encode(self.live_params(), window)

    def stream_step(self, state, chunk, fcfg):
        """One hop of incremental inference (stream.engine.stream_step under
        this engine's plan): (state, chunk [B, k*hop]) -> (state, logits)."""
        self._require_kwt("stream_step")
        step = self._stream_steps.get(fcfg)
        if step is None:
            from repro.stream import engine as stream_engine
            cfg = self.exec_cfg
            step = jax.jit(lambda p, s, c: stream_engine.stream_step(
                p, s, c, cfg, fcfg))
            self._stream_steps[fcfg] = step
        tr = _trace.active_tracer()
        if tr is None:
            return step(self.live_params(), state, chunk)
        with tr.span("stream_step", {"backend": self.backend.name}):
            lp = self._live_traced(tr)
            with tr.span("hop"):
                return jax.block_until_ready(step(lp, state, chunk))

    # -- LM serving entry points ------------------------------------------

    def init_decode_state(self, batch: int, max_len: int):
        return self._mod.init_decode_state(self.exec_cfg, batch, max_len)

    def prefill(self, tokens, state):
        if self._prefill is None:
            cfg = self.exec_cfg
            self._prefill = jax.jit(
                lambda p, t, s: self._mod.prefill(p, t, cfg, s))
        tr = _trace.active_tracer()
        if tr is None:
            return self._prefill(self.live_params(), tokens, state)
        with tr.span("prefill", {"backend": self.backend.name}):
            lp = self._live_traced(tr)
            with tr.span("encode"):
                return jax.block_until_ready(self._prefill(lp, tokens, state))

    def decode_step(self, token, state):
        if self._decode is None:
            cfg = self.exec_cfg
            self._decode = jax.jit(
                lambda p, t, s: self._mod.decode_step(p, t, cfg, s))
        tr = _trace.active_tracer()
        if tr is None:
            return self._decode(self.live_params(), token, state)
        with tr.span("decode_step", {"backend": self.backend.name}):
            lp = self._live_traced(tr)
            with tr.span("encode"):
                return jax.block_until_ready(self._decode(lp, token, state))

    # -- introspection -----------------------------------------------------

    @property
    def backend_name(self) -> str:
        return self.backend.name

    @property
    def interpret(self) -> Optional[bool]:
        """The plan-time Pallas decision (None: plan uses no kernels)."""
        uses = self.backend.uses_kernels or \
            self.exec_cfg.attn_impl == "flash_lut"
        return self.exec_cfg.kernel_interpret if uses else None

    @property
    def rom_bytes(self) -> int:
        """TRUE packed bytes of the integer weight image the plan deploys
        (nibble-packed below 5 bits; 0 when nothing is quantised).

        KWT-Tiny at the paper recipe: 1512 B of int8 weight ROM — the
        paper's 1.65 kB figure counts its 146 rank-1 params (biases,
        norm scales) at int8 too, which we keep float per §IV.  A 4-bit
        recipe halves this (±nibble padding).
        """
        return self.quantized_bytes[0] if self.quantized_bytes else 0

    @property
    def lut_bytes(self) -> int:
        """LUT ROM footprint of the plan (paper: 2.69 kB; 0 for float)."""
        return lutlib.make_lut_bank().rom_bytes if self.backend.uses_lut else 0

    @property
    def param_bytes(self) -> int:
        """Deployed parameter bytes: packed ints + residual floats when
        quantised, plain float tree bytes otherwise."""
        if self.quantized_bytes is not None:
            return sum(self.quantized_bytes)
        return _tree_bytes(self.params)

    @property
    def int_resident(self) -> bool:
        """True when the Engine's live tree holds stored-integer QTensors
        (the lut/pallas weight path) rather than a dequantised float copy."""
        return _has_qtensors(self.params)

    def describe(self, analyze: bool = False, cost: bool = False) -> str:
        """One-line plan summary.  ``analyze=True`` appends the static-
        analysis verdict (repro.analysis), running the pass pipeline on
        first use; a verdict cached by an earlier ``check_engine`` call
        is appended either way.  ``cost=True`` appends the static cost
        model's totals (repro.perf) plus the paper-style per-(stage, op)
        table priced on the RV32 MCU model — the one-stop answer to
        "what does this plan cost and where"."""
        if analyze and not hasattr(self, "_analysis_verdict"):
            from repro import analysis
            analysis.check_engine(self)
        q = "" if self.recipe is None else \
            f", w=2^{self.recipe.weight_exponent}" \
            f"/x=2^{self.recipe.input_exponent} " \
            f"int{self.recipe.bits} {self.recipe.rounding}" + \
            (" int-exec" if self.int_exec else
             " resident" if self.int_resident else "")
        interp = "" if self.interpret is None else \
            f", pallas={'interpret' if self.interpret else 'mosaic'}"
        attn = "" if self.exec_cfg.attn_impl == "xla" else \
            f", attn={self.exec_cfg.attn_impl}"
        verdict = getattr(self, "_analysis_verdict", None)
        verdict = f" | {verdict}" if verdict else ""
        line = (f"Engine[{self.backend.name}] {self.exec_cfg.name}: "
                f"params {self.param_bytes} B, rom {self.rom_bytes} B, "
                f"lut {self.lut_bytes} B{q}{interp}{attn}{verdict}")
        if cost:
            from repro import perf
            rep = perf.engine_cost(self, batch=1)
            mcu = perf.PAPER_MCU
            line += (f" | cost/fwd: {rep.flops:.0f} flops, "
                     f"{rep.bytes:.0f} B moved, AI {rep.intensity:.2f}, "
                     f"~{mcu.cycles(rep.flops, rep.bytes):.3g} "
                     f"{mcu.name} cycles\n" + rep.table(mcu))
        return line

    def _require_kwt(self, what: str):
        if self.exec_cfg.family != "kwt":
            raise NotImplementedError(
                f"{what} is a KWT streaming entry point; family="
                f"{self.exec_cfg.family!r} engines expose forward/prefill/"
                "decode_step")


class EngineHandle:
    """A swap-safe reference to the live Engine of a serving cell.

    Serving loops read ``handle.engine`` (or call the delegating entry
    points) each hop; ``cell.hotswap`` replaces the Engine atomically
    under the handle's lock after warming + probe-parity verification.
    Lane state (stream rings, detector state, decode caches) lives
    outside the Engine, so a swap changes only params + executables —
    in-flight lanes keep their positions and no hop is dropped.

    ``swap`` enforces plan compatibility by default: the incoming
    Engine must share the exec config (same arch dims + pinned modes)
    and a param tree of identical structure/shapes, so the serving
    loop's jitted programs keep their compiled executables and the swap
    costs one reference assignment, not a recompile mid-traffic.
    """

    def __init__(self, engine: Engine):
        self._lock = threading.Lock()
        self._engine = engine
        self._generation = 0
        self._live_cache = None          # (generation, unpacked float view)

    @property
    def engine(self) -> Engine:
        return self._engine

    @property
    def generation(self) -> int:
        """Bumps once per completed swap (serving loops key caches on it)."""
        return self._generation

    def live_params(self):
        """The current Engine's float operand tree, cached per generation
        (one unpack per swap instead of one per hop for integer-resident
        plans; see :meth:`Engine.live_params`)."""
        with self._lock:
            gen, eng = self._generation, self._engine
        cache = self._live_cache
        if cache is not None and cache[0] == gen:
            return cache[1]
        live = eng.live_params()
        self._live_cache = (gen, live)
        return live

    def swap(self, new_engine: Engine, *, strict: bool = True) -> Engine:
        """Install ``new_engine``; returns the Engine it replaced."""
        if strict:
            old = self._engine
            if new_engine.exec_cfg != old.exec_cfg:
                raise ValueError(
                    "hot-swap across exec configs would recompile the "
                    f"serving programs mid-traffic: {old.exec_cfg.name}/"
                    f"{old.backend.name} -> {new_engine.exec_cfg.name}/"
                    f"{new_engine.backend.name} (swap(strict=False) to "
                    "force)")
            old_shapes = [(getattr(x, "shape", None))
                          for x in jax.tree.leaves(old.params)]
            new_shapes = [(getattr(x, "shape", None))
                          for x in jax.tree.leaves(new_engine.params)]
            if old_shapes != new_shapes:
                raise ValueError("hot-swap param tree shape mismatch")
        with self._lock:
            old, self._engine = self._engine, new_engine
            self._generation += 1
            self._live_cache = None
        return old


def _has_qtensors(tree) -> bool:
    return any(isinstance(leaf, quant.QTensor) for leaf in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, quant.QTensor)))


def _recipe_from_tree(cfg, tree) -> QuantRecipe:
    """Reconstruct the deployment recipe of an already-quantised tree from
    its own QTensor metadata (bits / exponent / per-channel), so
    ``Engine.recipe`` and ``describe()`` report the artifact's actual
    policy rather than the config default.  Rounding is storage-
    irrelevant post-quantisation and keeps the config default."""
    qleaves = [leaf for leaf in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, quant.QTensor))
        if isinstance(leaf, quant.QTensor)]
    return QuantRecipe.from_config(
        cfg, bits=qleaves[0].bits,
        weight_exponent=min(q.exponent for q in qleaves),
        per_channel=any(q.axis_exponents is not None for q in qleaves))


def _lm_partial_resident(qtree: dict) -> dict:
    """LM partial residency: keep the big vocab-facing leaves (embedding
    table / untied head) packed for integer execution, dequantise the
    per-block stack.  ``lax.scan`` carries the blocks as stacked leaves
    and per-channel QTensor metadata (``axis_exponents`` over the last
    axis) has no leading layer axis to scan over, so block weights take
    the dequantise-first path; the embedding is consumed row-wise via
    ``quant.gather_descale`` (descale only the looked-up rows)."""
    packed = {k: v for k, v in qtree.items() if k in ("embed", "lm_head")}
    rest = {k: v for k, v in qtree.items() if k not in packed}
    return {**quant.dequantize_tree(rest), **packed}


def _pin_int_exec(exec_cfg, recipe: QuantRecipe):
    """Pin the integer-execution plan flavour onto the exec config: the
    activation quantiser shares the recipe's eq-9 semantics (input
    exponent, residual width), so layers and the artifact agree on the
    fixed-point grid by construction."""
    from repro.configs.base import QuantConfig
    qc = exec_cfg.quant if exec_cfg.quant is not None else QuantConfig()
    qc = dataclasses.replace(qc, input_exponent=recipe.input_exponent,
                             residual_bits=recipe.residual_bits)
    return exec_cfg.with_(int_exec=True, quant=qc)


def compile_model(cfg, params, backend="float",
                  recipe: QuantRecipe | None = None,
                  interpret: bool | None = None,
                  attention: str | None = None,
                  integer_resident: bool | None = None,
                  integer_exec: bool | None = None,
                  taps: bool = False) -> Engine:
    """Plan execution of ``params`` under ``backend``.

    ``recipe=None`` -> the backend's default policy: quantising backends
    (lut_float / lut / pallas) derive a QuantRecipe from ``cfg.quant``;
    the float backend leaves params untouched.  Passing an explicit
    recipe forces PTQ on any backend (e.g. float ops on quantised weights
    — Table IX's middle column).  ``params`` may also be an
    already-quantised QTensor tree (a packed QAT export artifact): it is
    deployed as-is, no float detour and no re-quantisation.

    ``integer_resident`` overrides the backend's weight-residency policy
    (default: ``lut``/``pallas`` keep the stored int8 / nibble-packed
    int4 QTensors live inside the jitted program and de-scale in the
    matmul epilogue — packed weight bytes; other backends deploy the
    dequantised float copy).  Integer residency currently covers the
    ``kwt`` family (the paper model whose layers consume QTensors);
    LM-scale families get PARTIAL residency under integer execution
    (embedding/head stay packed, scanned blocks dequantise — see
    ``_lm_partial_resident``) and otherwise fall back to
    dequantise-first.

    ``integer_exec`` overrides the backend's execution policy (default:
    ``lut``/``pallas`` integer-EXECUTE resident plans — linear layers
    quantise activations to the recipe's eq-9 grid and multiply the
    stored int payload directly, per-channel po2 requant in the
    epilogue, no per-call unpack stage).  ``integer_exec=False`` keeps
    the PR-5 dequantise-per-call resident plan, whose logits are
    bit-identical to dequantise-first; integer execution instead matches
    the Q8.24 fixed-point reference (activation rounding + INT16
    residual clip are part of the plan's math, as on the device).

    ``interpret`` overrides the plan-time Pallas interpret/Mosaic
    auto-decision (tests only).  ``attention`` overrides the backend's
    attention realisation: ``"flash_lut"`` routes cacheless attention
    through the flash-LUT Pallas kernel (``kernels.lut_attention`` —
    online softmax with the eq-11 ROM), ``"xla"`` keeps the chunked sdpa
    path.

    ``taps=True`` plans the quantisation-health aux: ``forward`` returns
    ``(logits, aux)`` where aux carries per-layer int8 saturation, LUT
    out-of-domain fractions and Q8.24 headroom (telemetry.taps).  Logits
    are served by the same untapped executable as a ``taps=False`` plan
    (bit-identical); taps off costs nothing (the flag is a plain Python
    branch, no recompile).
    """
    be = get_backend(backend)
    pre_quantized = _has_qtensors(params)
    if recipe is None and pre_quantized:
        recipe = _recipe_from_tree(cfg, params)
    elif recipe is None and be.quantize:
        recipe = QuantRecipe.from_config(cfg)
    qbytes = None
    int_exec = False
    exec_flag = be.int_exec if integer_exec is None else bool(integer_exec)
    if recipe is not None or pre_quantized:
        qtree = params if pre_quantized else recipe.quantize(params)
        # ROM footprint is the artifact's full packed image, independent
        # of which leaves the plan keeps resident.
        qbytes = quant.tree_quantized_bytes(qtree)
        if integer_resident is not None or cfg.family == "kwt":
            resident = (be.int_resident and cfg.family == "kwt"
                        if integer_resident is None
                        else bool(integer_resident))
            params = qtree if resident else quant.dequantize_tree(qtree)
            int_exec = exec_flag and resident
        elif exec_flag and be.int_resident and isinstance(qtree, dict) \
                and "embed" in qtree:
            params = _lm_partial_resident(qtree)
            int_exec = True
        else:
            params = quant.dequantize_tree(qtree)
    exec_cfg = be.configure(cfg, interpret=interpret, attention=attention)
    if int_exec:
        exec_cfg = _pin_int_exec(exec_cfg, recipe)
    return Engine(cfg=cfg, exec_cfg=exec_cfg, params=params, backend=be,
                  recipe=recipe, quantized_bytes=qbytes, taps=taps,
                  int_exec=int_exec)
