"""Engine: one compiled execution plan for one model + backend + recipe.

``compile_model(cfg, params, backend=..., recipe=...)`` is the single
entry point through which every launcher, example and benchmark selects
execution.  It resolves the backend (float / lut_float / lut / pallas),
applies the QuantRecipe PTQ when the backend calls for it, pins the
execution modes onto the config ONCE (including the Pallas
interpret-vs-Mosaic decision), and returns an ``Engine`` whose jitted
entry points all run that one plan:

    eng = runtime.compile_model(cfg, params, backend="lut")
    logits = eng.forward(mfcc)            # offline [B, F, T] -> [B, C]
    emb    = eng.embed_frames(frames)     # streaming building blocks
    logits = eng.encode_window(window)    #   (consumed by stream.engine)
    state, logits = eng.stream_step(state, chunk, fcfg)

LM families additionally expose ``init_decode_state`` / ``prefill`` /
``decode_step`` so ``launch/serve.py`` runs off the same object.

Contract (tests/test_runtime.py): for any backend, streaming logits are
bit-identical to that same engine's offline ``forward`` on the matching
audio window — the PR-2 float/LUT bit-identity guarantee restated at the
Engine level — and float/lut/pallas logits agree within the documented
PTQ + LUT-bin tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

from repro.core import lut as lutlib
from repro.core import quant
from repro.runtime.backends import Backend, get_backend
from repro.runtime.recipe import QuantRecipe

Pytree = Any


def _model_module(cfg):
    if cfg.family == "kwt":
        from repro.models import kwt
        return kwt
    from repro.launch import steps
    return steps.model_module(cfg)


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclasses.dataclass
class Engine:
    """A planned model: prepared params + pinned execution config.

    ``exec_cfg`` is the ONLY config that carries softmax_mode /
    act_approx / kernel_interpret different from the user's ``cfg`` —
    drivers that build their own fused jits (e.g. the streaming server's
    joint engine+detector hop) close over ``eng.exec_cfg`` and pass
    ``eng.params``, so execution policy still has a single source.
    """

    cfg: Any                        # the config compile_model was given
    exec_cfg: Any                   # cfg with the backend's modes pinned
    params: Pytree                  # PTQ-applied when the backend quantizes
    backend: Backend
    recipe: Optional[QuantRecipe]
    quantized_bytes: Optional[tuple] = None   # (int bytes, float bytes)

    def __post_init__(self):
        self._mod = _model_module(self.exec_cfg)
        cfg = self.exec_cfg
        self._forward = jax.jit(lambda p, x: self._mod.forward(p, x, cfg))
        self._embed = self._encode = self._prefill = self._decode = None
        self._stream_steps = {}
        if cfg.family == "kwt":
            self._embed = jax.jit(
                lambda p, fr: self._mod.embed_frames(p, fr, cfg))
            self._encode = jax.jit(
                lambda p, w: self._mod.encode_window(p, w, cfg))

    # -- inference entry points (all jitted, params passed as operands) ----

    def forward(self, x):
        """Offline forward: kwt mfcc [B,F,T] -> logits; LM tokens -> logits."""
        return self._forward(self.params, x)

    def embed_frames(self, frames):
        """[B, t, F] time-major frames -> [B, t, d] patch embeddings."""
        self._require_kwt("embed_frames")
        return self._embed(self.params, frames)

    def encode_window(self, window):
        """Assembled [B, T, d] window -> logits [B, n_classes]."""
        self._require_kwt("encode_window")
        return self._encode(self.params, window)

    def stream_step(self, state, chunk, fcfg):
        """One hop of incremental inference (stream.engine.stream_step under
        this engine's plan): (state, chunk [B, k*hop]) -> (state, logits)."""
        self._require_kwt("stream_step")
        step = self._stream_steps.get(fcfg)
        if step is None:
            from repro.stream import engine as stream_engine
            cfg = self.exec_cfg
            step = jax.jit(lambda p, s, c: stream_engine.stream_step(
                p, s, c, cfg, fcfg))
            self._stream_steps[fcfg] = step
        return step(self.params, state, chunk)

    # -- LM serving entry points ------------------------------------------

    def init_decode_state(self, batch: int, max_len: int):
        return self._mod.init_decode_state(self.exec_cfg, batch, max_len)

    def prefill(self, tokens, state):
        if self._prefill is None:
            cfg = self.exec_cfg
            self._prefill = jax.jit(
                lambda p, t, s: self._mod.prefill(p, t, cfg, s))
        return self._prefill(self.params, tokens, state)

    def decode_step(self, token, state):
        if self._decode is None:
            cfg = self.exec_cfg
            self._decode = jax.jit(
                lambda p, t, s: self._mod.decode_step(p, t, cfg, s))
        return self._decode(self.params, token, state)

    # -- introspection -----------------------------------------------------

    @property
    def backend_name(self) -> str:
        return self.backend.name

    @property
    def interpret(self) -> Optional[bool]:
        """The plan-time Pallas decision (None: plan uses no kernels)."""
        uses = self.backend.uses_kernels or \
            self.exec_cfg.attn_impl == "flash_lut"
        return self.exec_cfg.kernel_interpret if uses else None

    @property
    def rom_bytes(self) -> int:
        """LUT ROM footprint of the plan (paper: 2.69 kB; 0 for float)."""
        return lutlib.make_lut_bank().rom_bytes if self.backend.uses_lut else 0

    @property
    def param_bytes(self) -> int:
        """Deployed parameter bytes: int8 + residual-float when quantised,
        plain float tree bytes otherwise."""
        if self.quantized_bytes is not None:
            return sum(self.quantized_bytes)
        return _tree_bytes(self.params)

    def describe(self) -> str:
        q = "" if self.recipe is None else \
            f", w=2^{self.recipe.weight_exponent}" \
            f"/x=2^{self.recipe.input_exponent} {self.recipe.rounding}"
        interp = "" if self.interpret is None else \
            f", pallas={'interpret' if self.interpret else 'mosaic'}"
        attn = "" if self.exec_cfg.attn_impl == "xla" else \
            f", attn={self.exec_cfg.attn_impl}"
        return (f"Engine[{self.backend.name}] {self.exec_cfg.name}: "
                f"params {self.param_bytes} B, rom {self.rom_bytes} B{q}"
                f"{interp}{attn}")

    def _require_kwt(self, what: str):
        if self.exec_cfg.family != "kwt":
            raise NotImplementedError(
                f"{what} is a KWT streaming entry point; family="
                f"{self.exec_cfg.family!r} engines expose forward/prefill/"
                f"decode_step")


def compile_model(cfg, params, backend="float",
                  recipe: QuantRecipe | None = None,
                  interpret: bool | None = None,
                  attention: str | None = None) -> Engine:
    """Plan execution of ``params`` under ``backend``.

    ``recipe=None`` -> the backend's default policy: quantising backends
    (lut_float / lut / pallas) derive a QuantRecipe from ``cfg.quant``;
    the float backend leaves params untouched.  Passing an explicit
    recipe forces PTQ on any backend (e.g. float ops on quantised weights
    — Table IX's middle column).  ``interpret`` overrides the plan-time
    Pallas interpret/Mosaic auto-decision (tests only).  ``attention``
    overrides the backend's attention realisation: ``"flash_lut"`` routes
    cacheless attention through the flash-LUT Pallas kernel
    (``kernels.lut_attention`` — online softmax with the eq-11 ROM),
    ``"xla"`` keeps the chunked sdpa path.
    """
    be = get_backend(backend)
    if recipe is None and be.quantize:
        recipe = QuantRecipe.from_config(cfg)
    qbytes = None
    if recipe is not None:
        qtree = recipe.quantize(params)
        qbytes = quant.tree_quantized_bytes(qtree)
        params = quant.dequantize_tree(qtree)
    exec_cfg = be.configure(cfg, interpret=interpret, attention=attention)
    return Engine(cfg=cfg, exec_cfg=exec_cfg, params=params, backend=be,
                  recipe=recipe, quantized_bytes=qbytes)
