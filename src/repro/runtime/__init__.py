"""repro.runtime — one Engine/Backend API for float, LUT and Pallas execution.

Owns execution policy end to end: which numeric path runs the model
(``Backend`` registry), how params are quantised (``QuantRecipe``), and
the single planning entry point ``compile_model(cfg, params,
backend=..., recipe=...) -> Engine``.  No call site outside this package
mutates ``softmax_mode`` / ``act_approx`` or calls ``quantize_tree``
directly — see README §repro.runtime for the migration table.
"""

from repro.runtime.backends import (Backend, available_backends, get_backend,
                                    plan_interpret, register_backend)
from repro.runtime.engine import Engine, EngineHandle, compile_model
from repro.runtime.recipe import QuantRecipe


def quantize_params(params, cfg, rounding: str = "nearest"):
    """Compat shim for the old ``launch.serve.quantize_params``: PTQ per
    paper §IV (int8 weights at the Table V exponent, norms/biases float),
    returned as the dequantised float tree the engine runs."""
    return QuantRecipe.from_config(cfg, rounding=rounding).apply(params)


__all__ = ["Backend", "Engine", "EngineHandle", "QuantRecipe",
           "available_backends", "compile_model", "get_backend",
           "plan_interpret", "quantize_params", "register_backend"]
