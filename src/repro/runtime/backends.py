"""Backend registry: *which* numeric/kernel realisation runs the model.

The paper's pipeline has three executable readings of the same math —
exact float ops, the jnp LUT reference (the ROM contents as gathers), and
the Pallas kernels — and deployment work (sub-8-bit streaming KWS,
arXiv:2207.06920; edge-transformer surveys) treats that choice as a
first-class decision.  A ``Backend`` bundles the decision: the
softmax/activation modes it pins on the config, whether params get the
eq-9 PTQ by default, and — for the kernel path — whether Pallas runs in
interpret mode or compiled Mosaic, decided ONCE here at plan time (the
old per-call ``jax.default_backend()`` probe in ``kernels.ops`` is no
longer consulted on the runtime path).
"""

from __future__ import annotations

import dataclasses

import jax


def plan_interpret() -> bool:
    """The one plan-time interpret/compiled decision: interpret everywhere
    except a real TPU (the validation mode mandated for this container)."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class Backend:
    """One execution policy.

    ``quantize``: apply the QuantRecipe PTQ to params by default.
    ``uses_lut``: the 2.69 kB ROM bank is live (Engine.lut_bytes > 0).
    ``uses_kernels``: softmax/GELU execute as Pallas kernels; the config
    gets ``kernel_interpret`` pinned to the plan-time decision.
    ``int_resident``: the Engine keeps the quantised weights in their
    stored integer form (int8 / nibble-packed int4 QTensors) rather than
    a plan-time dequantised float copy.
    ``int_exec``: the plan integer-EXECUTES: linear layers quantise
    their inputs (eq 9, the recipe's input exponent) and multiply the
    stored payload directly with a per-channel po2 requant epilogue
    (``quant.int_exec_einsum``) — no per-call ``dequantize_tree`` unpack
    stage, no float weight view in the plan.  ``runtime.compile_model``
    resolves the actual plan flavour (residency x family) and pins
    ``cfg.int_exec``; non-executing resident plans keep the PR-5
    dequantise-per-call path (``quant.qt_einsum``), bit-identical to
    dequantise-first.
    """

    name: str
    description: str
    softmax_mode: str
    act_approx: str
    quantize: bool = False
    uses_lut: bool = False
    uses_kernels: bool = False
    int_resident: bool = False
    int_exec: bool = False
    attention: str = "xla"         # xla | flash_lut (kernels.lut_attention)

    def configure(self, cfg, *, interpret: bool | None = None,
                  attention: str | None = None):
        """Pin this backend's execution modes onto a ModelConfig.  The ONLY
        place in the tree that mutates softmax_mode / act_approx /
        attn_impl.  ``attention`` overrides the backend's registered
        attention realisation (the ``compile_model(attention=...)`` knob)."""
        attn = self.attention if attention is None else attention
        if attn not in ("xla", "flash_lut"):
            raise ValueError(f"unknown attention impl {attn!r}; "
                             "available: xla, flash_lut")
        kw = dict(softmax_mode=self.softmax_mode, act_approx=self.act_approx,
                  attn_impl=attn)
        if self.uses_kernels or attn == "flash_lut":
            kw["kernel_interpret"] = (plan_interpret() if interpret is None
                                      else bool(interpret))
        return cfg.with_(**kw)


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    """Register (or override) a backend under ``backend.name``."""
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name) -> Backend:
    if isinstance(name, Backend):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; available: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(Backend(
    "float", "exact XLA float ops, float params (paper's baseline)",
    softmax_mode="exact", act_approx="exact"))

register_backend(Backend(
    "lut_float", "jnp LUT softmax with float carry + LUT GELU, PTQ params "
                 "(Table IX column 3: quantised but unaccelerated)",
    softmax_mode="lut", act_approx="lut", quantize=True, uses_lut=True))

register_backend(Backend(
    "lut", "jnp Q8.24 LUT reference: fixed-point softmax + LUT GELU, "
           "integer-resident AND integer-executing PTQ params (the "
           "'+Hardware' path, Table IX column 4)",
    softmax_mode="lut_fixed", act_approx="lut", quantize=True, uses_lut=True,
    int_resident=True, int_exec=True))

register_backend(Backend(
    "pallas", "Pallas kernels for softmax/GELU (interpret on CPU, compiled "
              "Mosaic on TPU — decided at plan time), integer-resident and "
              "integer-executing PTQ params",
    softmax_mode="pallas", act_approx="pallas", quantize=True, uses_lut=True,
    uses_kernels=True, int_resident=True, int_exec=True))
