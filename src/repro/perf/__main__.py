"""CLI: cost tables, host calibration, and the bench regression gate.

    python -m repro.perf cost --arch kwt-tiny --backend lut [--mcu]
    python -m repro.perf calibrate
    python -m repro.perf regress [--history BENCH_history.jsonl]
    python -m repro.perf regress --selftest

``regress`` exits non-zero on any gated regression (CI's required
step).  ``--selftest`` proves the gate can fail: it seeds a throwaway
ledger with a healthy baseline plus a 2× latency regression and a
1-byte ROM growth, and exits 0 only if the gate (a) trips on both and
(b) passes once the regressions are removed — the same
prove-the-checker-can-fail discipline as ``repro.analysis``'s mutation
self-tests.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _cmd_cost(args) -> int:
    import jax

    from repro import perf, runtime
    from repro.configs import registry
    from repro.launch import steps

    cfg = registry.get(args.arch).smoke if args.smoke \
        else registry.get(args.arch)
    params = steps.model_module(cfg).init_params(cfg, jax.random.PRNGKey(0))
    machine = perf.PAPER_MCU if args.mcu else perf.host_machine()
    for backend in args.backends:
        eng = runtime.compile_model(cfg, params, backend=backend)
        rep = perf.engine_cost(eng, batch=args.batch)
        print(f"\n## {args.arch} · backend={backend} · batch={args.batch} "
              f"· machine={machine.name}")
        print(rep.table(machine))
        t = machine.time_s(rep.flops, rep.bytes)
        print(f"roofline bound: {machine.verdict(rep.intensity)} "
              f"(AI {rep.intensity:.2f} vs ridge {machine.ridge:.2f}), "
              f"est {machine.cycles(rep.flops, rep.bytes):.3g} cycles "
              f"({t * 1e6:.1f} us at {machine.clock_hz / 1e6:.0f} MHz)")
    return 0


def _cmd_calibrate(args) -> int:
    from repro import perf

    m = perf.calibrate(reps=args.reps)
    print(json.dumps(m.to_dict(), indent=2))
    print(f"ridge point: {m.ridge:.2f} flops/byte", file=sys.stderr)
    return 0


def _selftest() -> int:
    """Seed a throwaway ledger; the gate must trip on a 2× latency and a
    ROM-bytes regression, and pass with the regressions removed."""
    from repro import perf

    prov = {"git_commit": "selftest", "jax_version": "-", "device": "-",
            "timestamp": "-", "calibration": None}
    base = [perf.entry("kwt-tiny", "lut", 64, 600.0 + i, "us_per_forward",
                       rom_bytes=1500, prov=prov) for i in range(3)]

    with tempfile.TemporaryDirectory() as td:
        bad = os.path.join(td, "bad.jsonl")
        perf.append(bad, base + [perf.entry(
            "kwt-tiny", "lut", 64, 1200.0, "us_per_forward",
            rom_bytes=1501, prov=prov)])
        v_bad = perf.regress(bad)
        good = os.path.join(td, "good.jsonl")
        perf.append(good, base + [perf.entry(
            "kwt-tiny", "lut", 64, 610.0, "us_per_forward",
            rom_bytes=1500, prov=prov)])
        v_good = perf.regress(good)

    ok = (len(v_bad.failures) == 2 and not v_bad.ok and v_good.ok)
    print(v_bad.summary())
    print(v_good.summary())
    print(f"selftest: gate {'trips and clears as required' if ok else 'BROKEN'}")
    return 0 if ok else 1


def _cmd_regress(args) -> int:
    from repro import perf

    if args.selftest:
        return _selftest()
    v = perf.regress(args.history, tol=args.tol, window=args.window)
    print(v.summary())
    return 0 if v.ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.perf")
    sub = ap.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("cost", help="static cost table of an Engine plan")
    c.add_argument("--arch", default="kwt-tiny")
    c.add_argument("--backends", nargs="+", default=["lut"])
    c.add_argument("--batch", type=int, default=1)
    c.add_argument("--smoke", action="store_true",
                   help="use the arch's smoke config")
    c.add_argument("--mcu", action="store_true",
                   help="price on the paper's RV32 MCU model instead of "
                        "a calibrated host")
    c.set_defaults(fn=_cmd_cost)

    c = sub.add_parser("calibrate", help="measure this host's roofline")
    c.add_argument("--reps", type=int, default=5)
    c.set_defaults(fn=_cmd_calibrate)

    c = sub.add_parser("regress", help="gate newest bench entries against "
                                       "their rolling baselines")
    c.add_argument("--history", default="BENCH_history.jsonl")
    c.add_argument("--tol", type=float, default=0.15)
    c.add_argument("--window", type=int, default=5)
    c.add_argument("--selftest", action="store_true",
                   help="prove the gate trips on a seeded 2x regression")
    c.set_defaults(fn=_cmd_regress)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
