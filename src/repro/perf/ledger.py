"""Append-only bench ledger + rolling-baseline regression gate.

``BENCH_history.jsonl`` is the repo's bench trajectory: every sweep row
from ``benchmarks/run.py`` / ``stream_bench.py`` / ``qat_bench.py``
lands here as one JSON line with full provenance (git commit, jax
version, device, roofline calibration id), so "did this PR make `lut`
slower" is a query, not archaeology.  CI restores the ledger from a
rolling cache, appends the run's smoke sweep, and gates on
``python -m repro.perf regress``.

Entry schema (one line each, append-only, never rewritten)::

    {"arch": .., "backend": .., "batch": ..,        # the key
     "latency": .., "latency_unit": "mean_us" | "ms_per_hop"
                                    | "ms_per_token" | "ratio_mean_us",
     "rom_bytes": ..,                               # packed image bytes
     "extra": {...},                                # free-form row tail
     "provenance": {git_commit, jax_version, device, timestamp,
                    calibration}}

The gate compares the NEWEST entry per (arch, backend, batch,
latency_unit) key against the **median of the previous ``window``
entries** for that key (median, not last: one noisy CI run must not
move the baseline) and fails on >``tol`` latency growth or ANY
rom_bytes growth — ROM is deterministic, so any increase is a real
packaging regression, while latency gets slack for host noise.  Keys
with no prior history pass (first entry seeds the baseline).
"""

from __future__ import annotations

import dataclasses
import datetime
import json
import os
import subprocess
from typing import Optional

HISTORY_PATH = "BENCH_history.jsonl"
DEFAULT_TOL = 0.15
DEFAULT_WINDOW = 5


# -- provenance -------------------------------------------------------------

def git_commit(cwd: Optional[str] = None) -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def provenance(calibration=None) -> dict:
    """Identity block stamped on ledger entries AND BENCH_*.json headers
    (same dict in both places, so artifacts and history cross-reference)."""
    import jax
    dev = jax.devices()[0]
    return {
        "git_commit": git_commit(),
        "jax_version": jax.__version__,
        "device": f"{jax.default_backend()}:{dev.device_kind}",
        "host_cpus": os.cpu_count(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "calibration": getattr(calibration, "id", calibration),
    }


# -- entries ----------------------------------------------------------------

def entry(arch: str, backend: str, batch: int, latency: float,
          latency_unit: str, rom_bytes: int = 0, extra: Optional[dict] = None,
          prov: Optional[dict] = None) -> dict:
    return {"arch": arch, "backend": backend, "batch": int(batch),
            "latency": float(latency), "latency_unit": latency_unit,
            "rom_bytes": int(rom_bytes), "extra": extra or {},
            "provenance": prov or provenance()}


def append(path: str, entries) -> int:
    """Append entries as JSONL; returns how many were written."""
    if isinstance(entries, dict):
        entries = [entries]
    entries = list(entries)
    with open(path, "a") as f:
        for e in entries:
            f.write(json.dumps(e, sort_keys=True) + "\n")
    return len(entries)


def read(path: str) -> list:
    """All ledger entries in append order (missing file → empty history)."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _key(e: dict) -> tuple:
    return (e.get("arch"), e.get("backend"), e.get("batch"),
            e.get("latency_unit"))


def _median(xs: list) -> float:
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


# -- the gate ---------------------------------------------------------------

@dataclasses.dataclass
class Verdict:
    """Outcome of the regression gate over one ledger."""

    checked: int
    skipped: int                       # keys with no prior baseline
    failures: list                     # human-readable failure strings

    @property
    def ok(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        head = (f"regress: {self.checked} keys checked, "
                f"{self.skipped} unseeded, {len(self.failures)} failed")
        return "\n".join([head] + [f"  FAIL {f}" for f in self.failures])


def regress(path: str = HISTORY_PATH, tol: float = DEFAULT_TOL,
            window: int = DEFAULT_WINDOW) -> Verdict:
    """Gate the newest entry of every key against its rolling baseline."""
    by_key: dict = {}
    for e in read(path):
        by_key.setdefault(_key(e), []).append(e)

    checked = skipped = 0
    failures = []
    for key, hist in sorted(by_key.items(), key=lambda kv: str(kv[0])):
        newest, prior = hist[-1], hist[:-1][-window:]
        if not prior:
            skipped += 1
            continue
        checked += 1
        name = "/".join(str(k) for k in key)
        base_lat = _median([p["latency"] for p in prior])
        if base_lat > 0 and newest["latency"] > (1.0 + tol) * base_lat:
            failures.append(
                f"{name}: latency {newest['latency']:.4g} "
                f"{newest['latency_unit']} vs baseline {base_lat:.4g} "
                f"(+{100 * (newest['latency'] / base_lat - 1):.1f}% "
                f"> {100 * tol:.0f}% tol) "
                f"[commit {newest['provenance'].get('git_commit')}]")
        base_rom = _median([p.get("rom_bytes", 0) for p in prior])
        if newest.get("rom_bytes", 0) > base_rom:
            failures.append(
                f"{name}: rom_bytes {newest['rom_bytes']} vs baseline "
                f"{base_rom:.0f} (any growth fails — packing is "
                f"deterministic) "
                f"[commit {newest['provenance'].get('git_commit')}]")
    return Verdict(checked=checked, skipped=skipped, failures=failures)
