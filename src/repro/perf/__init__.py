"""repro.perf — the performance-accounting layer.

The paper's claim structure is a cost ledger (per-op cycles, 26M →
5.5M); this package gives every Engine plan the same treatment:

* :mod:`repro.perf.cost` — static FLOPs / bytes-moved / arithmetic-
  intensity model over compiled jaxprs, attributed to named stages
  (unpack / featurise / embed / encode) and op classes (matmul /
  softmax / gelu / norm / fft), with a paper-style estimated-cycles
  column;
* :mod:`repro.perf.roofline` — machine models (the paper's RV32 MCU,
  TPU v5e datasheet, a *measured* calibration of the current host) and
  the ``achieved_pct_of_roof`` / bound-verdict annotation every bench
  row carries;
* :mod:`repro.perf.ledger` — the append-only ``BENCH_history.jsonl``
  with provenance, and the rolling-baseline regression gate behind
  ``python -m repro.perf regress``.

The serve-side counterpart is :class:`repro.telemetry.flight
.FlightRecorder`, which uses :func:`cost.stream_hop_cost` stage weights
to attribute anomalous hops post-mortem.
"""

from repro.perf.cost import (CostLine, CostReport, engine_cost,
                             program_cost, stream_hop_cost)
from repro.perf.ledger import (HISTORY_PATH, Verdict, append, entry,
                               provenance, read, regress)
from repro.perf.roofline import (PAPER_MCU, V5E, MachineModel,
                                 annotate_row, calibrate, host_machine,
                                 roofline_terms)

__all__ = [
    "CostLine", "CostReport", "engine_cost", "program_cost",
    "stream_hop_cost",
    "MachineModel", "PAPER_MCU", "V5E", "calibrate", "host_machine",
    "annotate_row", "roofline_terms",
    "HISTORY_PATH", "Verdict", "append", "entry", "provenance", "read",
    "regress",
]
