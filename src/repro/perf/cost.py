"""Static cost model: FLOPs / bytes moved per named stage of an Engine plan.

The paper's headline result is a cost ledger — per-op clock cycles
(Figs 3-5, Table IX) pinning GELU/SoftMax as the 26M-cycle inference's
hot spots and auditing the 5x win down to 5.5M cycles.  This module is
the repo's analogue at jaxpr granularity: it walks any compiled Engine
program with the same traversal machinery as ``repro.analysis`` and
accumulates, per equation,

* **flops** — 2*M*N*K for ``dot_general``/``conv``, output size for
  element-wise math, input size for reductions, ``5*n*log2(n)`` for FFT
  stages; layout ops (reshape/transpose/broadcast) are free;
* **bytes moved** — operand + result buffer bytes of every
  compute-bearing or data-moving equation (a flat-memory traffic model:
  each operand is read once, each result written once; layout-only ops
  move nothing — XLA folds them into consumers);
* **arithmetic intensity** — flops / bytes, the roofline x-axis.

Each equation is attributed to a **stage** (``unpack`` / ``featurise``
/ ``embed`` / ``encode`` / ``detector`` — from the trace-time user
frames, the same provenance the residency pass keys whitelists on) and
an **op class** (``matmul`` / ``softmax`` / ``gelu`` / ``norm`` /
``fft`` / ``other``), so the table reads like the paper's: one row per
(stage, op), with an estimated-cycles column once a
:class:`repro.perf.roofline.MachineModel` prices it.

Call-like primitives are handled with multipliers: ``scan`` bodies
count ``length`` times, ``pallas_call`` kernels count once per grid
step over their *block-shaped* body (so Pallas padding shows up as real
extra work — which it is), ``cond`` contributes its most expensive
branch, ``while`` bodies count once (flagged in ``notes``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax

from repro.analysis import jaxpr_walk as jw

# -- equation classification ------------------------------------------------

# op class by trace-time frame function name (innermost frame wins)
_OP_BY_FUNC = {
    "softmax": ("softmax_exact", "softmax_lut", "fixed_softmax",
                "masked_softmax", "softmax", "_pre_shift", "lut_softmax",
                "_softmax_kernel"),
    "gelu": ("gelu_exact", "gelu_lut", "gelu", "lut_gelu", "silu",
             "sigmoid_lut", "softplus", "sqrelu", "_gelu_kernel",
             "activation"),
    "norm": ("apply_norm", "_rms"),
    "fft": ("_frame_features", "mfcc"),
    # integer-execution epilogue/prologue work (quant.int_exec_einsum):
    # activation quantise, container moves, per-channel requant, row
    # gather-descale — everything around the integer GEMM itself (the
    # dot_general still classifies as matmul by primitive fallback)
    "requant": ("quantize_act", "requant", "int_container",
                "gather_descale"),
    # dispatch-trivial contractions that int_exec_einsum unrolls into an
    # elementwise multiply-add chain (quant.matmul_unrolled): still the
    # linear algebra, priced as MACs in _walk so matmul_flops stays
    # backend-invariant (2*M*N*K, the dot_general convention)
    "matmul": ("matmul_unrolled",),
}

# stage by frame function name, scanned innermost -> outermost
_STAGE_BY_FUNC = {
    "embed_frames": "embed",
    "encode_window": "encode",
    "dequantize_tree": "unpack",
    "dequantize": "unpack",
    "unpack_po2": "unpack",
    "unpack_payload": "unpack",
}

# stage by the repo file a frame lives in (used when no function matches)
_STAGE_BY_FILE = {
    "features.py": "featurise",
    "detector.py": "detector",
}

# layout/metadata primitives: no flops, no modelled memory traffic (XLA
# folds them into their consumers; counting them would double-charge)
_FREE_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "bitcast_convert_type", "stop_gradient", "optimization_barrier",
    "copy", "iota", "slice", "rev", "split",
})

# one flop per output element
_ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "rem", "max", "min", "neg", "abs", "sign",
    "exp", "exp2", "expm1", "log", "log1p", "tanh", "sin", "cos", "erf",
    "erfc", "erf_inv", "rsqrt", "sqrt", "cbrt", "logistic", "pow",
    "integer_pow", "floor", "ceil", "round", "clamp", "nextafter",
    "select_n", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "and", "or", "xor", "not", "eq", "ne", "lt",
    "le", "gt", "ge", "is_finite", "add_any", "exp2_p",
})

# one flop per *input* element (reductions)
_REDUCTIONS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cumprod", "cummax",
    "cummin", "reduce_precision", "logsumexp",
})


def _out_avals(eqn):
    return [v.aval for v in eqn.outvars if hasattr(v, "aval")]


def _in_avals(eqn):
    return [v.aval for v in eqn.invars if hasattr(v, "aval")]


def _size(avals) -> float:
    return float(sum(int(a.size) for a in avals))


def eqn_flops(eqn) -> float:
    """Modelled floating(/integer)-op count of one equation."""
    prim = eqn.primitive.name
    if prim in _FREE_PRIMS:
        return 0.0
    if prim == "dot_general":
        (lc, _), _ = eqn.params["dimension_numbers"]
        lhs = eqn.invars[0].aval
        k = 1
        for ax in lc:
            k *= int(lhs.shape[ax])
        return 2.0 * _size(_out_avals(eqn)[:1]) * k
    if prim == "conv_general_dilated":
        rhs = eqn.invars[1].aval
        out = _out_avals(eqn)[0]
        dn = eqn.params["dimension_numbers"]
        k = int(rhs.size) // int(rhs.shape[dn.rhs_spec[0]])
        return 2.0 * float(out.size) * k
    if prim == "fft":
        n = int(eqn.invars[0].aval.shape[-1])
        batch = _size(_in_avals(eqn)[:1]) / max(n, 1)
        return 5.0 * batch * n * max(math.log2(max(n, 2)), 1.0)
    if prim in _ELEMENTWISE:
        return _size(_out_avals(eqn)[:1])
    if prim in _REDUCTIONS:
        return _size(_in_avals(eqn)[:1])
    return 0.0


def eqn_bytes(eqn) -> float:
    """Modelled memory traffic of one equation (operands read + results
    written once; layout-only primitives move nothing)."""
    if eqn.primitive.name in _FREE_PRIMS:
        return 0.0
    return float(sum(jw.aval_bytes(a) for a in _in_avals(eqn))
                 + sum(jw.aval_bytes(a) for a in _out_avals(eqn)))


def classify(eqn, default_stage: str) -> tuple[str, str]:
    """(stage, op) attribution of one equation from its user frames."""
    frames = jw.user_frames(eqn)
    op = None
    stage = None
    for i, f in enumerate(frames):
        fn = f.function_name
        fname = f.file_name.rsplit("/", 1)[-1]
        if op is None:
            for label, funcs in _OP_BY_FUNC.items():
                if fn in funcs:
                    op = label
                    break
        if stage is None:
            stage = _STAGE_BY_FUNC.get(fn)
            if stage is None and i == 0:
                stage = _STAGE_BY_FILE.get(fname)
    if op is None:
        op = "matmul" if eqn.primitive.name in (
            "dot_general", "conv_general_dilated") else "other"
    return stage or default_stage, op


# -- accumulation -----------------------------------------------------------

@dataclasses.dataclass
class CostLine:
    """Accumulated cost of one (stage, op) cell of the table."""

    stage: str
    op: str
    flops: float = 0.0
    bytes: float = 0.0
    eqns: int = 0

    @property
    def intensity(self) -> float:
        return self.flops / self.bytes if self.bytes else 0.0


@dataclasses.dataclass
class CostReport:
    """Per-(stage, op) cost lines of one (or several merged) programs."""

    lines: dict = dataclasses.field(default_factory=dict)
    notes: list = dataclasses.field(default_factory=list)

    def add(self, stage: str, op: str, flops: float, bytes_: float,
            mult: float = 1.0) -> None:
        line = self.lines.get((stage, op))
        if line is None:
            line = self.lines[(stage, op)] = CostLine(stage, op)
        line.flops += mult * flops
        line.bytes += mult * bytes_
        line.eqns += 1

    def merge(self, other: "CostReport") -> "CostReport":
        for (stage, op), line in other.lines.items():
            cur = self.lines.get((stage, op))
            if cur is None:
                self.lines[(stage, op)] = dataclasses.replace(line)
            else:
                cur.flops += line.flops
                cur.bytes += line.bytes
                cur.eqns += line.eqns
        self.notes.extend(other.notes)
        return self

    # -- totals -----------------------------------------------------------

    @property
    def flops(self) -> float:
        return sum(ln.flops for ln in self.lines.values())

    @property
    def bytes(self) -> float:
        return sum(ln.bytes for ln in self.lines.values())

    @property
    def matmul_flops(self) -> float:
        """dot/conv flops only — backend-invariant for identical math
        (the LUT/Pallas backends change softmax/GELU realisation, never
        the linear algebra; tests/test_perf.py pins this)."""
        return sum(ln.flops for ln in self.lines.values()
                   if ln.op == "matmul")

    @property
    def intensity(self) -> float:
        return self.flops / self.bytes if self.bytes else 0.0

    def by_stage(self) -> dict:
        out: dict = {}
        for ln in self.lines.values():
            cur = out.setdefault(ln.stage, CostLine(ln.stage, "*"))
            cur.flops += ln.flops
            cur.bytes += ln.bytes
            cur.eqns += ln.eqns
        return out

    def stage_weights(self, machine=None) -> dict:
        """Relative time share per stage (flight-recorder attribution):
        modelled stage time on ``machine`` (roofline max of compute and
        memory terms), normalised to sum to 1; flops share if no machine."""
        stages = self.by_stage()
        if machine is None:
            tot = sum(ln.flops for ln in stages.values()) or 1.0
            return {s: ln.flops / tot for s, ln in stages.items()}
        t = {s: machine.time_s(ln.flops, ln.bytes)
             for s, ln in stages.items()}
        tot = sum(t.values()) or 1.0
        return {s: v / tot for s, v in t.items()}

    # -- rendering --------------------------------------------------------

    def rows(self, machine=None) -> list[dict]:
        """Table rows (dicts), paper-style: one per (stage, op) plus an
        estimated-cycles column when a MachineModel prices the plan."""
        out = []
        for (stage, op) in sorted(self.lines):
            ln = self.lines[(stage, op)]
            row = {"stage": stage, "op": op, "flops": round(ln.flops),
                   "bytes_moved": round(ln.bytes),
                   "arithmetic_intensity": round(ln.intensity, 4),
                   "eqns": ln.eqns}
            if machine is not None:
                row["est_cycles"] = round(machine.cycles(ln.flops, ln.bytes))
            out.append(row)
        return out

    def table(self, machine=None) -> str:
        cols = ["stage", "op", "flops", "bytes_moved",
                "arithmetic_intensity", "eqns"]
        if machine is not None:
            cols.append("est_cycles")
        rows = self.rows(machine)
        head = "| " + " | ".join(cols) + " |"
        sep = "|" + "|".join("---" for _ in cols) + "|"
        body = ["| " + " | ".join(str(r[c]) for c in cols) + " |"
                for r in rows]
        total = {"stage": "**total**", "op": "", "flops": round(self.flops),
                 "bytes_moved": round(self.bytes),
                 "arithmetic_intensity": round(self.intensity, 4),
                 "eqns": sum(ln.eqns for ln in self.lines.values())}
        if machine is not None:
            total["est_cycles"] = round(machine.cycles(self.flops,
                                                       self.bytes))
        body.append("| " + " | ".join(str(total[c]) for c in cols) + " |")
        return "\n".join([head, sep] + body)

    def to_dict(self, machine=None) -> dict:
        return {"flops": round(self.flops),
                "bytes_moved": round(self.bytes),
                "matmul_flops": round(self.matmul_flops),
                "arithmetic_intensity": round(self.intensity, 4),
                "lines": self.rows(machine),
                "notes": list(self.notes)}


# -- jaxpr walking ----------------------------------------------------------

def _grid_size(eqn) -> float:
    gm = eqn.params.get("grid_mapping")
    grid = tuple(getattr(gm, "grid", ()) or ())
    n = 1.0
    for g in grid:
        try:
            n *= float(g)
        except TypeError:      # symbolic/dynamic grid dim: count once
            pass
    return n


def _branch_jaxprs(eqn):
    return [jw.closed_to_open(b) for b in eqn.params.get("branches", ())]


def _walk(jaxpr, mult: float, default_stage: str, rep: CostReport) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        subs = list(jw.sub_jaxprs(eqn))
        if subs:
            # call-like primitive: charge the nested program, not the call
            if prim == "cond":
                best = None
                for b in _branch_jaxprs(eqn):
                    sub_rep = CostReport()
                    _walk(b, mult, default_stage, sub_rep)
                    if best is None or sub_rep.flops > best.flops:
                        best = sub_rep
                if best is not None:
                    rep.merge(best)
                continue
            sub_mult = mult
            if prim == "scan":
                sub_mult = mult * float(eqn.params.get("length", 1))
            elif prim == "pallas_call":
                sub_mult = mult * _grid_size(eqn)
            elif prim == "while":
                rep.notes.append(
                    "while body counted once (static trip count unknown)")
            for sub in subs:
                _walk(sub, sub_mult, default_stage, rep)
            continue
        stage, op = classify(eqn, default_stage)
        flops = eqn_flops(eqn)
        if op == "matmul" and prim in _ELEMENTWISE:
            # unrolled MAC chain (quant.matmul_unrolled): each product is
            # a multiply-accumulate (2 flops), the explicit adds are the
            # accumulates already priced in — total 2*M*N*K, matching
            # the dot_general this chain replaces bit-for-bit
            flops = 2.0 * flops if prim == "mul" else 0.0
        rep.add(stage, op, flops, eqn_bytes(eqn), mult)


def program_cost(fn, *args, stage: str = "forward") -> CostReport:
    """Cost of ``fn(*args)``'s jaxpr; ``stage`` labels unattributed eqns."""
    closed = jax.make_jaxpr(fn)(*args)
    rep = CostReport()
    _walk(closed.jaxpr, 1.0, stage, rep)
    return rep


# -- Engine-level entry points ----------------------------------------------

def _unpack_cost(engine) -> Optional[CostReport]:
    """Cost of the per-call unpack program — None for float plans AND
    for integer-executing plans (no unpack stage exists; the eliminated
    work is the int-exec flavour's headline saving)."""
    if not engine.int_resident or engine.int_exec:
        return None
    from repro.core import quant
    return program_cost(quant.dequantize_tree, engine.params,
                        stage="unpack")


def _live_structs(engine):
    """The operand tree the model executables actually run on.

    Integer-EXECUTING plans consume the packed QTensors directly —
    tracing with them routes ``linear`` through ``quant.int_exec_einsum``
    and charges the quantise/requant epilogue where it really runs.

    Non-executing integer-resident plans feed ``live_params()`` (the
    transient float view) to the model jits — tracing with the packed
    QTensors instead would route ``linear`` through the inline-dequant
    path and charge unpack work to embed/encode twice.  ``eval_shape``
    gives the view's shapes without materialising it.
    """
    if not engine.int_resident or engine.int_exec:
        return engine.params
    from repro.core import quant
    return jax.eval_shape(quant.dequantize_tree, engine.params)


def engine_cost(engine, x=None, batch: int = 1) -> CostReport:
    """Full per-forward cost of an Engine plan (paper-table shape).

    Covers everything ``Engine.forward`` executes: the separate jitted
    unpack program of integer-resident plans (stage ``unpack``) plus the
    model program — KWT traced as its ``embed_frames``/``encode_window``
    factorisation so the stage split matches the telemetry span names;
    LM families land in one ``encode`` stage with per-op rows.
    """
    import jax.numpy as jnp

    from repro import analysis

    cfg = engine.exec_cfg
    if x is None:
        x = analysis.example_input(cfg, batch)
    rep = CostReport()
    up = _unpack_cost(engine)
    if up is not None:
        rep.merge(up)
    lp = _live_structs(engine)
    if cfg.family == "kwt":
        f, t = cfg.input_dim
        frames = jnp.zeros((x.shape[0], t, f), jnp.float32)
        window = jnp.zeros((x.shape[0], t, cfg.d_model),
                           jnp.dtype(cfg.dtype))
        rep.merge(program_cost(
            lambda p, fr: engine._mod.embed_frames(p, fr, cfg),
            lp, frames, stage="embed"))
        rep.merge(program_cost(
            lambda p, w: engine._mod.encode_window(p, w, cfg),
            lp, window, stage="encode"))
    else:
        rep.merge(program_cost(
            lambda p, xx: engine._mod.forward(p, xx, cfg),
            lp, x, stage="encode"))
    return rep


def stream_hop_cost(engine, fcfg, batch: int = 1, chunk_hops: int = 1,
                    feature_ingest: bool = False) -> CostReport:
    """Cost of one streaming hop under an Engine plan: the jitted
    ``stream.engine.stream_step`` (audio ingest: featurise + embed +
    encode) or ``stream_step_frames`` (edge-featurised ingest), plus the
    unpack program of integer-resident plans.  The detector step is not
    modelled (its per-hop work is a handful of [B] element-wise ops)."""
    import jax.numpy as jnp

    from repro.stream import engine as stream_engine

    cfg = engine.exec_cfg
    state = stream_engine.init_stream_state(cfg, fcfg, batch)
    rep = CostReport()
    up = _unpack_cost(engine)
    if up is not None:
        rep.merge(up)
    lp = _live_structs(engine)
    if feature_ingest:
        chunk = jnp.zeros((batch, chunk_hops, cfg.input_dim[0]),
                          jnp.float32)
        rep.merge(program_cost(
            lambda p, s, c: stream_engine.stream_step_frames(p, s, c, cfg),
            lp, state, chunk, stage="encode"))
    else:
        chunk = jnp.zeros((batch, chunk_hops * fcfg.hop_len), jnp.float32)
        rep.merge(program_cost(
            lambda p, s, c: stream_engine.stream_step(p, s, c, cfg, fcfg),
            lp, state, chunk, stage="encode"))
    return rep
