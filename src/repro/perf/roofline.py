"""Roofline machine models + host calibration for achieved-vs-peak rows.

A :class:`MachineModel` is the three-number summary the roofline model
needs — peak FLOP/s, memory bandwidth, and a clock for the paper-style
estimated-cycles column.  Two canonical models ship:

* :data:`PAPER_MCU` — a single-issue in-order RV32 at 250 MHz with a
  4-byte/cycle memory port, the class of core the paper's cycle counts
  come from (Table IX: 26M cycles baseline, 5.5M accelerated).  The
  ``est_mcu_cycles`` column in BENCH_runtime.json prices each backend's
  plan on this model so the repo's numbers land in the paper's units.
* :data:`V5E` — TPU v5e datasheet numbers; ``launch.mesh`` re-exports
  its constants so the launch-planning arithmetic and the perf layer
  share one source of truth.

:func:`calibrate` measures the *current host* instead of trusting a
datasheet: a jitted matmul for peak FLOP/s and a streaming element-wise
pass for memory bandwidth, best-of-``reps`` to strip scheduler noise.
Benchmarks combine the calibrated model with the static cost model
(:mod:`repro.perf.cost`) via :func:`roofline_terms` to stamp every
sweep row with ``achieved_pct_of_roof`` and a compute-vs-memory-bound
verdict — the achieved-vs-peak fraction the ROADMAP's Pallas item asks
for.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Peak envelope of one machine: the roofline's two ceilings + clock."""

    name: str
    peak_flops: float           # FLOP/s at the compute roof
    mem_bw: float               # bytes/s at the memory roof
    clock_hz: float = 1e9      # for the estimated-cycles column
    source: str = "datasheet"  # "datasheet" | "measured"

    @property
    def ridge(self) -> float:
        """Arithmetic intensity (flops/byte) where the roofs intersect."""
        return self.peak_flops / self.mem_bw if self.mem_bw else 0.0

    def attainable(self, intensity: float) -> float:
        """Roofline ceiling (FLOP/s) at the given arithmetic intensity."""
        return min(self.peak_flops, intensity * self.mem_bw)

    def verdict(self, intensity: float) -> str:
        return "compute-bound" if intensity >= self.ridge else "memory-bound"

    def time_s(self, flops: float, bytes_moved: float) -> float:
        """Roofline time bound: the slower of the compute and memory
        terms (perfect overlap of the two pipes)."""
        t = 0.0
        if self.peak_flops:
            t = flops / self.peak_flops
        if self.mem_bw:
            t = max(t, bytes_moved / self.mem_bw)
        return t

    def cycles(self, flops: float, bytes_moved: float) -> float:
        """Estimated clock cycles of (flops, bytes) on this machine —
        the unit of the paper's Table IX ledger."""
        return self.time_s(flops, bytes_moved) * self.clock_hz

    @property
    def id(self) -> str:
        """Short provenance identity for ledger entries."""
        return (f"{self.name}:{self.peak_flops:.3g}F/"
                f"{self.mem_bw:.3g}B@{self.clock_hz:.3g}Hz")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# The paper's deployment class: single-issue in-order RV32 (Ibex-like),
# 1 MAC-class op/cycle, a 32-bit memory port (4 B/cycle).  250 MHz is a
# nominal embedded clock — cycles, not seconds, are the comparable unit.
PAPER_MCU = MachineModel(name="rv32-mcu", peak_flops=250e6 * 1.0,
                         mem_bw=250e6 * 4.0, clock_hz=250e6)

# TPU v5e datasheet envelope (single chip).  launch.mesh re-exports
# these so dryrun cost arithmetic and perf share one source.
V5E_PEAK_FLOPS_BF16 = 197e12
V5E_PEAK_FLOPS_INT8 = 394e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9
V5E = MachineModel(name="tpu-v5e", peak_flops=V5E_PEAK_FLOPS_BF16,
                   mem_bw=V5E_HBM_BW, clock_hz=940e6)


# -- host calibration -------------------------------------------------------

def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate(n: int = 1024, stream_mb: int = 64,
              reps: int = 5) -> MachineModel:
    """Measure the current host's roofline envelope.

    * peak FLOP/s: jitted ``n×n @ n×n`` float32 matmul (XLA's best
      dense kernel on every backend) → ``2n³ / best_time``;
    * memory bandwidth: jitted ``x + 1`` over a ``stream_mb``-MB array,
      far past any cache → ``(read + write) / best_time``.

    Best-of-``reps`` strips scheduler noise; both programs are warmed
    before timing so compile time never pollutes the envelope.  The
    result is *measured attainable* peak, which is the honest roof for
    ``achieved_pct_of_roof`` — a datasheet roof no kernel can reach
    would make every row look artificially bad.
    """
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    jax.block_until_ready(mm(a))                      # compile
    peak = 2.0 * n ** 3 / _best_of(lambda: mm(a), reps)

    m = stream_mb * (1 << 20) // 4
    x = jnp.ones((m,), jnp.float32)
    add = jax.jit(lambda v: v + 1.0)
    jax.block_until_ready(add(x))
    bw = 2.0 * 4.0 * m / _best_of(lambda: add(x), reps)

    return MachineModel(name=f"measured-{jax.default_backend()}",
                        peak_flops=peak, mem_bw=bw, clock_hz=1e9,
                        source="measured")


_CACHED: dict = {}


def host_machine(refresh: bool = False) -> MachineModel:
    """Process-cached :func:`calibrate` — benchmarks calibrate once and
    stamp every row of a sweep with the same machine identity."""
    if refresh or "m" not in _CACHED:
        _CACHED["m"] = calibrate()
    return _CACHED["m"]


# -- row annotation ---------------------------------------------------------

def roofline_terms(flops: float, bytes_moved: float, measured_s: float,
                   machine: MachineModel) -> dict:
    """The columns every sweep row carries: modelled cost, achieved
    throughput against the machine's roof at this program's arithmetic
    intensity, and the compute-vs-memory-bound verdict.

    ``achieved_pct_of_roof`` > 100% is meaningful, not an error: the
    cost model's traffic term counts every operand/result byte, but a
    cache-resident working set (KWT-Tiny's is a few KB) never pays the
    measured DRAM bandwidth, so the intensity-limited roof underprices
    the machine.  ``achieved_pct_of_peak`` is the unconditional
    achieved-vs-compute-peak fraction (the ROADMAP's column) and is the
    number to watch for "how far from as-fast-as-the-hardware-allows".
    """
    ai = flops / bytes_moved if bytes_moved else 0.0
    roof = machine.attainable(ai)
    achieved = flops / measured_s if measured_s > 0 else 0.0
    return {
        "flops": round(flops),
        "bytes_moved": round(bytes_moved),
        "arithmetic_intensity": round(ai, 4),
        "achieved_flops_per_s": round(achieved),
        "achieved_pct_of_roof": round(100.0 * achieved / roof, 2)
        if roof else 0.0,
        "achieved_pct_of_peak": round(100.0 * achieved
                                      / machine.peak_flops, 3)
        if machine.peak_flops else 0.0,
        "bound": machine.verdict(ai),
    }


def annotate_row(row: dict, cost, measured_s: float,
                 machine: MachineModel) -> dict:
    """Merge :func:`roofline_terms` for a CostReport into ``row``."""
    row.update(roofline_terms(cost.flops, cost.bytes, measured_s, machine))
    return row
