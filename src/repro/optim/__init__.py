from repro.optim import adamw  # noqa: F401
from repro.optim.adamw import HParams  # noqa: F401
