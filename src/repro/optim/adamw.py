"""AdamW with optional int8 power-of-2-quantised moments.

The int8 moments are the paper's eq-9 primitive applied to optimizer state
(beyond-paper, DESIGN.md §3): each moment tensor is stored as int8 values
plus one power-of-2 scale exponent (dynamic, per tensor), making a 340B
model's training state fit a single 256-chip v5e pod:
  f32 moments: params 2B + grads 2B + m 4B + v 4B = 12 B/param -> 4.08 TB
  int8 moments: 2 + 2 + 1 + 1 + eps           =  6 B/param -> 2.04 TB

Functional API (pytree in/out, fully jit-able under pjit):
  init(params, hp)                 -> opt_state
  update(grads, state, params, hp) -> (new_params, new_state)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HParams:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    int8_moments: bool = False


def schedule(step, hp: HParams):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(hp.warmup_steps, 1)
    prog = jnp.clip((step - hp.warmup_steps)
                    / jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0, 1)
    cos = hp.min_lr_ratio + (1 - hp.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * jnp.where(step < hp.warmup_steps, warm, cos)


# --- int8 moment codec (dynamic power-of-2 scale, eq 9) --------------------

def _q8_encode(x):
    maxabs = jnp.max(jnp.abs(x))
    # scale = 2^e with 127 * 2^e >= maxabs  (power-of-2, paper eq 9)
    e = jnp.ceil(jnp.log2(jnp.maximum(maxabs, 1e-30) / 127.0))
    scale = jnp.exp2(e)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _q8_decode(enc):
    return enc["q"].astype(jnp.float32) * enc["scale"]


def init(params, hp: HParams):
    """Moments mirror the params; int8 moments carry a power-of-2 scale —
    per layer-slice for stacked-layer subtrees (see update())."""
    def zero_moment(p, stacked):
        if hp.int8_moments:
            scale_shape = (p.shape[0],) if stacked else ()
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.ones(scale_shape, jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    def tree_moment(params):
        assert isinstance(params, dict)
        return {key: jax.tree.map(
            lambda p, s=(key in STACKED_KEYS): zero_moment(p, s), sub)
            for key, sub in params.items()}

    return {
        "m": tree_moment(params),
        "v": tree_moment(params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs, hp: HParams):
    """Moment shardings mirror the parameter shardings (ZeRO-ish)."""
    from jax.sharding import PartitionSpec as P

    def like(spec, stacked):
        if hp.int8_moments:
            return {"q": spec, "scale": P(None) if stacked else P()}
        return spec

    def tree_like(specs):
        return {key: jax.tree.map(
            lambda sp, s=(key in STACKED_KEYS): like(sp, s), sub,
            is_leaf=lambda x: isinstance(x, P))
            for key, sub in specs.items()}

    return {
        "m": tree_like(param_specs),
        "v": tree_like(param_specs),
        "step": P(),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks")


def _is_enc(hp):
    return (lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}) \
        if hp.int8_moments else (lambda x: False)


def _update_subtree(g_t, m_t, v_t, p_t, *, lr, clip, step, hp):
    """Element-wise AdamW over one same-structure subtree."""
    def leaf(g, m_enc, v_enc, p):
        g = g.astype(jnp.float32) * clip
        m = _q8_decode(m_enc) if hp.int8_moments else m_enc
        v = _q8_decode(v_enc) if hp.int8_moments else v_enc
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        mhat = m / (1 - hp.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - hp.b2 ** step.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + hp.eps)
        if p.ndim > 1:                       # decoupled WD on matrices only
            upd = upd + hp.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if hp.int8_moments:
            return new_p, _q8_encode(m), _q8_encode(v)
        return new_p, m, v

    is_enc = _is_enc(hp)
    flat_p, treedef = jax.tree.flatten(p_t)
    flat_g = jax.tree.leaves(g_t)
    flat_m = jax.tree.leaves(m_t, is_leaf=is_enc)
    flat_v = jax.tree.leaves(v_t, is_leaf=is_enc)
    out = [leaf(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]),
            jax.tree.unflatten(treedef, [o[2] for o in out]))


def update(grads, state, params, hp: HParams, *, scan_stacked: bool = True):
    """One AdamW step.

    Stacked-layer subtrees (params["blocks"] etc., leading axis = n_layers)
    are updated under a ``lax.scan`` over the layer axis so the f32
    grad/moment intermediates of one *layer slice* are live at a time —
    without this, a 340B model's optimizer transients alone exceed HBM
    (measured: 36 GB/device -> fits after; DESIGN.md §3).
    """
    step = state["step"] + 1
    lr = schedule(step, hp)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-9))
    kw = dict(lr=lr, clip=clip, step=step, hp=hp)
    is_enc = _is_enc(hp)

    new_p, new_m, new_v = ({}, {}, {})
    assert isinstance(params, dict)
    for key in params:
        g_t, m_t, v_t, p_t = (grads[key], state["m"][key], state["v"][key],
                              params[key])
        stacked = scan_stacked and key in STACKED_KEYS and \
            all(leaf.ndim >= 1 for leaf in jax.tree.leaves(p_t))
        if not stacked:
            new_p[key], new_m[key], new_v[key] = _update_subtree(
                g_t, m_t, v_t, p_t, **kw)
        else:
            def body(_, slices):
                g, m, v, p = slices
                return None, _update_subtree(g, m, v, p, **kw)

            _, (np_, nm, nv) = jax.lax.scan(body, None, (g_t, m_t, v_t, p_t))
            new_p[key], new_m[key], new_v[key] = np_, nm, nv
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
