"""Deterministic, restart-exact data pipelines.

Both pipelines are *stateless-seeded*: batch(step) is a pure function of
(seed, step), so a restarted job resumes mid-epoch exactly (no iterator
state in checkpoints) and every data-parallel shard derives its slice from
the same global batch definition — the fault-tolerance contract in
DESIGN.md §3.

1. ``lm_batch``      — synthetic token stream (Zipfian-ish) for the LM archs.
2. ``keyword_batch`` — synthetic GSC-style 2-class MFCC keyword data for
   KWT ("dog"/"notdog", paper §III): class-conditional spectro-temporal
   patterns + noise.  Deterministic surrogate for the (offline) GSC set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(seed: int, step: int, *, global_batch: int, seq_len: int,
             vocab_size: int):
    """Synthetic next-token data: tokens + shifted labels."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    # Zipf-ish marginal via squared uniform -> favours low token ids
    u = jax.random.uniform(key, (global_batch, seq_len + 1))
    toks = (jnp.square(u) * (vocab_size - 1)).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def keyword_batch(seed: int, step: int, *, batch: int, input_dim=(16, 26),
                  n_classes: int = 2):
    """Class-conditional MFCC-like features.

    Class c gets a characteristic ridge at frequency band f_c with a
    class-specific temporal chirp, plus i.i.d. noise — enough structure
    that KWT-Tiny separates classes within a few hundred steps, mirroring
    the paper's "dog"/"notdog" setup.
    """
    f, t = input_dim
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    noise = jax.random.normal(k2, (batch, f, t))
    freqs = jnp.arange(f)[None, :, None].astype(jnp.float32)
    times = jnp.arange(t)[None, None, :].astype(jnp.float32)
    # overlapping class centres + per-sample jitter: hard enough that the
    # float model lands ~0.9 and the quantisation staircase is visible
    jitter = jax.random.normal(k4, (batch, 1, 1)) * 2.0
    centre = (f / 2.0 + jitter
              + (labels[:, None, None].astype(jnp.float32) - 0.5) * 2.5)
    chirp = centre + (labels[:, None, None].astype(jnp.float32) - 0.5) \
        * times / t * 3.0
    ridge = jnp.exp(-0.5 * jnp.square(freqs - chirp))
    amp = 1.1 + 0.3 * jax.random.normal(k3, (batch, 1, 1))
    mfcc = amp * ridge + noise
    return {"mfcc": mfcc, "labels": labels}


def gsc_eval_set(seed: int, *, n: int, input_dim=(16, 26), n_classes: int = 2,
                 batch: int = 64):
    """Fixed eval batches (deterministic, disjoint fold from training)."""
    return [keyword_batch(seed + 10_000, i, batch=batch, input_dim=input_dim,
                          n_classes=n_classes)
            for i in range(int(np.ceil(n / batch)))]
