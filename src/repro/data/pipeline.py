"""Deterministic, restart-exact data pipelines.

Both pipelines are *stateless-seeded*: batch(step) is a pure function of
(seed, step), so a restarted job resumes mid-epoch exactly (no iterator
state in checkpoints) and every data-parallel shard derives its slice from
the same global batch definition — the fault-tolerance contract in
DESIGN.md §3.

1. ``lm_batch``      — synthetic token stream (Zipfian-ish) for the LM archs.
2. ``keyword_batch`` — synthetic GSC-style 2-class MFCC keyword data for
   KWT ("dog"/"notdog", paper §III): class-conditional spectro-temporal
   patterns + noise.  Deterministic surrogate for the (offline) GSC set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(seed: int, step: int, *, global_batch: int, seq_len: int,
             vocab_size: int):
    """Synthetic next-token data: tokens + shifted labels."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    # Zipf-ish marginal via squared uniform -> favours low token ids
    u = jax.random.uniform(key, (global_batch, seq_len + 1))
    toks = (jnp.square(u) * (vocab_size - 1)).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def keyword_batch(seed: int, step: int, *, batch: int, input_dim=(16, 26),
                  n_classes: int = 2):
    """Class-conditional MFCC-like features.

    Class c gets a characteristic ridge at frequency band f_c with a
    class-specific temporal chirp, plus i.i.d. noise — enough structure
    that KWT-Tiny separates classes within a few hundred steps, mirroring
    the paper's "dog"/"notdog" setup.

    ``n_classes > 2`` is the GSC-35-style *fine-grained* surrogate: class
    c is a variant of binary class ``c % 2`` — the same primary ridge, plus
    a variant-specific secondary ridge (classes 0/1 carry none, so they
    coincide exactly with the binary task's two classes).  A model trained
    on the 35-class task therefore transfers to the binary deployment by
    grouping columns — the head-reduction route ``repro.qat.distill``
    reproduces from the paper (§III, 35 -> 2 classes).
    """
    f, t = input_dim
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    noise = jax.random.normal(k2, (batch, f, t))
    freqs = jnp.arange(f)[None, :, None].astype(jnp.float32)
    times = jnp.arange(t)[None, None, :].astype(jnp.float32)
    # overlapping class centres + per-sample jitter: hard enough that the
    # float model lands ~0.9 and the quantisation staircase is visible
    jitter = jax.random.normal(k4, (batch, 1, 1)) * 2.0
    coarse = (labels % 2)[:, None, None].astype(jnp.float32)
    centre = f / 2.0 + jitter + (coarse - 0.5) * 2.5
    chirp = centre + (coarse - 0.5) * times / t * 3.0
    ridge = jnp.exp(-0.5 * jnp.square(freqs - chirp))
    if n_classes > 2:
        variant = (labels // 2)[:, None, None].astype(jnp.float32)
        vfreq = jnp.mod(1.3 + (variant - 1.0) * 1.9, float(f))
        ridge = ridge + jnp.where(
            variant > 0,
            0.7 * jnp.exp(-0.5 * jnp.square(freqs - vfreq)), 0.0)
    amp = 1.1 + 0.3 * jax.random.normal(k3, (batch, 1, 1))
    mfcc = amp * ridge + noise
    return {"mfcc": mfcc, "labels": labels}


def gsc_eval_set(seed: int, *, n: int, input_dim=(16, 26), n_classes: int = 2,
                 batch: int = 64):
    """Fixed eval batches (deterministic, disjoint fold from training)."""
    return [keyword_batch(seed + 10_000, i, batch=batch, input_dim=input_dim,
                          n_classes=n_classes)
            for i in range(int(np.ceil(n / batch)))]


# ---------------------------------------------------------------------------
# Raw-audio surrogates for the streaming subsystem (repro.stream): the same
# stateless-seeded contract, one level earlier in the signal chain — the
# waveform the MFCC frontend (stream/features.py) consumes, instead of the
# pre-made features above.
# ---------------------------------------------------------------------------

SAMPLE_RATE = 16_000


def _keyword_chirp(n_samples: int, t0, amp, sample_rate=SAMPLE_RATE):
    """The synthetic "dog" sound: an amplitude-enveloped rising chirp
    (1->3 kHz), broad-band enough to light up several mel bands."""
    t = (jnp.arange(n_samples, dtype=jnp.float32) - t0) / sample_rate
    dur = n_samples / sample_rate
    f0, f1 = 1000.0, 3000.0
    phase = 2.0 * jnp.pi * (f0 * t + 0.5 * (f1 - f0) / dur * t * t)
    env = jnp.square(jnp.sin(jnp.pi * jnp.clip(t / dur, 0.0, 1.0)))
    return amp * env * jnp.sin(phase)


def keyword_audio_batch(seed: int, step: int, *, batch: int,
                        n_samples: int, n_classes: int = 2,
                        sample_rate: int = SAMPLE_RATE):
    """Class-conditional raw audio: label 1 carries the chirp keyword over
    noise, label 0 is noise alone.  Featurised by ``stream.features.mfcc``
    this trains KWT end to end from the waveform (paper §III, with audio
    standing in for the GSC recordings)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    noise = 0.12 * jax.random.normal(k2, (batch, n_samples))
    amp = 0.5 + 0.2 * jax.random.uniform(k3, (batch, 1))
    jitter = jax.random.uniform(k4, (batch, 1)) * 0.2 * n_samples
    chirp = jax.vmap(lambda t0, a: _keyword_chirp(n_samples, t0, a[0],
                                                  sample_rate))(jitter, amp)
    audio = noise + jnp.where((labels > 0)[:, None], chirp, 0.0)
    return {"audio": audio, "labels": labels}


def keyword_event_stream(seed: int, stream_id: int, *, n_hops: int,
                         hop_len: int = 160, event_len_hops: int = 26,
                         mean_gap_hops: int = 60,
                         sample_rate: int = SAMPLE_RATE):
    """An unbounded-stream surrogate: ``n_hops * hop_len`` samples of noise
    with keyword chirps at random positions.  Host-side numpy (this feeds
    the serving loop, mirroring ``launch/serve.py``'s request queue).

    Returns ``(audio [n_hops*hop_len] f32, events)`` where ``events`` is a
    list of (start_hop, end_hop) ground-truth keyword intervals.
    """
    rng = np.random.RandomState((seed * 100_003 + stream_id) % (2**31 - 1))
    n = n_hops * hop_len
    audio = 0.12 * rng.randn(n).astype(np.float32)
    events, hop = [], int(rng.randint(10, mean_gap_hops))
    ev_len = event_len_hops * hop_len
    while hop + event_len_hops < n_hops:
        s = hop * hop_len
        audio[s:s + ev_len] += np.asarray(
            _keyword_chirp(ev_len, 0.0, 0.5 + 0.2 * rng.rand(), sample_rate))
        events.append((hop, hop + event_len_hops))
        hop += event_len_hops + int(rng.randint(mean_gap_hops // 2,
                                                2 * mean_gap_hops))
    return audio, events
