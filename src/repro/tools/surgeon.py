"""Model surgeon: the paper's §III iterative down-scaling methodology.

"Through an iterative approach, the layers with the least impact on
inference accuracy were removed.  These were found to be the depth
layers."  This tool scores each transformer block (and optionally MLP
width) by the loss increase when it is ablated (identity-bypassed) on a
calibration set, and emits the removal ranking that drives a
KWT-1 -> KWT-Tiny style shrink.

  PYTHONPATH=src python -m repro.tools.surgeon      # demo on KWT
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import kwt


def ablation_scores(params, cfg, batches, loss_fn):
    """Loss increase per ablated block.  Returns [(layer, delta_loss)]."""
    def mean_loss(p):
        return float(jnp.mean(jnp.stack(
            [loss_fn(p, b, cfg) for b in batches])))

    base = mean_loss(params)
    scores = []
    for i in range(len(params["blocks"])):
        ablated = dict(params)
        blocks = list(params["blocks"])
        bp = jax.tree.map(jnp.copy, blocks[i])
        # identity-bypass: zero the block's output projections so the
        # residual stream passes through unchanged
        for key in ("attn", "mlp"):
            sub = dict(bp[key])
            out_w = "wo" if key == "attn" else ("w2" if "w2" in sub else "w_down")
            sub[out_w] = jnp.zeros_like(sub[out_w])
            bp = {**bp, key: sub}
        blocks = blocks[:i] + [bp] + blocks[i + 1:]
        ablated["blocks"] = blocks
        scores.append((i, mean_loss(ablated) - base))
    return base, sorted(scores, key=lambda kv: kv[1])


def shrink_plan(scores, keep: int):
    """Blocks to delete (lowest impact first), paper §III style."""
    return [i for i, _ in scores[:len(scores) - keep]]


def shrink_params(params, scores, keep: int):
    """Apply a shrink plan: drop the ``len(blocks) - keep`` lowest-impact
    blocks and keep the survivors in their original order (residual-stream
    order matters).  The result is a valid parameter tree for
    ``cfg.with_(n_layers=keep)`` — the ablation-driven teacher/student
    initialiser consumed by ``repro.qat.distill``.
    """
    drop = set(shrink_plan(scores, keep))
    blocks = [bp for i, bp in enumerate(params["blocks"]) if i not in drop]
    assert len(blocks) == keep, (len(blocks), keep)
    return {**params, "blocks": blocks}


def main():
    from repro.configs import registry
    from repro.data import pipeline

    cfg = registry.get("kwt-1").config.with_(n_layers=4)
    params = kwt.init_params(cfg, jax.random.PRNGKey(0))
    batches = [pipeline.keyword_batch(0, i, batch=32,
                                      input_dim=cfg.input_dim,
                                      n_classes=cfg.n_classes)
               for i in range(2)]
    base, scores = ablation_scores(params, cfg, batches, kwt.loss_fn)
    print(f"base loss {base:.4f}")
    for i, d in scores:
        print(f"block {i}: +{d:.5f} loss when ablated")
    print("remove order for depth=1 target:", shrink_plan(scores, keep=1))
    shrunk = shrink_params(params, scores, keep=1)
    print(f"shrunk tree: {len(shrunk['blocks'])} block(s), "
          f"{kwt.count_params(shrunk)} params (from {kwt.count_params(params)})")


if __name__ == "__main__":
    main()
