"""Pallas TPU kernel: INT8 x INT8 -> INT32 matmul with power-of-2 rescale.

The paper's quantised pipeline (§IV) multiplies INT8 weights by INT8
activations, accumulates into wider integers, and rescales by bit shifts
(eq 9's 2^y scales).  On a v5e the MXU executes int8 x int8 -> int32
natively at 2x the bf16 rate (394 TOPS), so the paper's "no-FPU" trick
becomes a throughput/bandwidth optimisation (DESIGN.md §2).

Tiling: classic (M/bm, N/bn, K/bk) grid, K innermost; an int32 VMEM scratch
tile carries the partial accumulation across K steps; the epilogue applies
the shift rescale (acc_exp -> out_exp) and writes f32 or a clipped int16
residual (the paper's INT16 intermediate type).

MXU alignment: block defaults 128/128/128 (int8 tiles are (32,128)-packed;
multiples of 128 keep the MXU fully fed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _int8_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, shift: int,
                        out_int16: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        acc = (acc >> shift) if shift >= 0 else (acc << (-shift))
        if out_int16:
            acc = jnp.clip(acc, -(2**15), 2**15 - 1)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "shift", "out_int16", "block_m", "block_n", "block_k", "interpret"))
def int8_matmul_raw(x_int: jnp.ndarray, w_int: jnp.ndarray, *, shift: int = 0,
                    out_int16: bool = False,
                    block_m: int = DEFAULT_BM, block_n: int = DEFAULT_BN,
                    block_k: int = DEFAULT_BK,
                    interpret: bool = True) -> jnp.ndarray:
    """[M,K]i8 @ [K,N]i8 -> int32 (or int16) with epilogue shift ``>> shift``."""
    m, k = x_int.shape
    k2, n = w_int.shape
    assert k == k2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    out_dtype = jnp.int16 if out_int16 else jnp.int32
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, n_k=n_k, shift=shift,
                          out_int16=out_int16),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
    )(x_int, w_int)


def _acc_scratch(bm: int, bn: int):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bn), jnp.int32)
