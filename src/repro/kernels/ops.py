"""Public jit'd wrappers for the Pallas kernels.

Handles: arbitrary leading batch dims, padding to block multiples, dtype
plumbing, and interpret-mode auto-detection (interpret=True on CPU — the
validation mode mandated for this container; compiled Mosaic on real TPU).

The framework's model code calls these entry points; ``mode`` plumbing in
``repro.models`` decides between exact XLA ops, jnp LUT reference, and these
kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import int8_matmul as _mm
from repro.kernels import lut_attention as _attn
from repro.kernels import lut_gelu as _gelu
from repro.kernels import lut_softmax as _sm


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), size


def lut_gelu(x: jnp.ndarray, *, interp: bool = False,
             interpret: bool | None = None) -> jnp.ndarray:
    """Piecewise LUT GELU over any-shaped input."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    padded, m0 = _pad_to(flat, 0, 8)
    padded, n0 = _pad_to(padded, 1, 128)
    bm = min(_gelu.DEFAULT_BLOCK_M, padded.shape[0])
    bn = min(_gelu.DEFAULT_BLOCK_N, padded.shape[1])
    while padded.shape[0] % bm:
        bm //= 2
    while padded.shape[1] % bn:
        bn //= 2
    out = _gelu.lut_gelu_2d(padded, interp=interp, block_m=bm, block_n=bn,
                            interpret=_auto_interpret(interpret))
    return out[:m0, :n0].reshape(shape)


def lut_softmax(x: jnp.ndarray, *, fixed: bool = True,
                interpret: bool | None = None) -> jnp.ndarray:
    """LUT softmax along the last axis of any-shaped input.

    Padding lanes are filled with a very negative score: they land in the
    z=10 clip bin and contribute e^{-10} each; we slice them away before
    returning (their contribution to the sum is the same leak the paper's
    own clip has for off-range scores).
    """
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    padded, m0 = _pad_to(flat, 0, 8)
    out = _sm.lut_softmax_2d(padded, fixed=fixed,
                             interpret=_auto_interpret(interpret))
    return out[:m0].reshape(shape)


def int8_matmul(x_int: jnp.ndarray, w_int: jnp.ndarray, *, x_exp: int,
                w_exp: int, out_exp: int | None = None,
                residual_bits: int = 32,
                interpret: bool | None = None) -> jnp.ndarray:
    """Quantised matmul -> dequantised f32 (contract matches ref.int8_matmul)."""
    m, k = x_int.shape
    k2, n = w_int.shape
    xp, _ = _pad_to(x_int, 0, 8)
    xp, _ = _pad_to(xp, 1, 128)
    wp, _ = _pad_to(w_int, 0, 128)
    wp, _ = _pad_to(wp, 1, 128)
    acc_exp = x_exp + w_exp
    out_exp = acc_exp if out_exp is None else out_exp
    bm = 128
    while xp.shape[0] % bm:
        bm //= 2
    out = _mm.int8_matmul_raw(
        xp, wp, shift=acc_exp - out_exp, out_int16=(residual_bits == 16),
        block_m=bm, interpret=_auto_interpret(interpret))
    return out[:m, :n].astype(jnp.float32) * (2.0 ** (-out_exp))


def lut_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, use_lut: bool = True,
                  scale: float | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Flash attention with LUT-exp softmax; [B,H,L,D] GQA layout."""
    lq, lk = q.shape[2], k.shape[2]
    block_q = _attn.DEFAULT_BQ
    block_k = _attn.DEFAULT_BK
    while lq % min(block_q, lq):
        block_q //= 2
    while lk % min(block_k, lk):
        block_k //= 2
    return _attn.lut_attention(
        q, k, v, causal=causal, use_lut=use_lut, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))
