"""Public jit'd wrappers for the Pallas kernels.

Handles: arbitrary leading batch dims, padding to block multiples, dtype
plumbing, and interpret-mode selection.  ``repro.runtime`` decides
interpret-vs-Mosaic ONCE at plan time and passes the literal value down;
the ``interpret=None`` auto-probe remains only for direct/ad-hoc callers
(tests, notebooks) that bypass the runtime.

All block geometry goes through two shared helpers:

  ``pad_to_block(x, axis, mult)``  - pad an axis up to a block multiple,
                                     returning the original size for the
                                     final slice-back;
  ``fit_block(size, preferred)``   - shrink a preferred block edge by
                                     powers of two until it divides the
                                     (padded) size.

which every wrapper below (GELU, softmax, matmul, attention) uses instead
of the previously duplicated pad/shrink loops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import int8_matmul as _mm
from repro.kernels import lut_attention as _attn
from repro.kernels import lut_gelu as _gelu
from repro.kernels import lut_softmax as _sm


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def pad_to_block(x: jnp.ndarray, axis: int, mult: int, value=0.0):
    """Pad ``axis`` up to a multiple of ``mult``; returns (padded, size0)
    where ``size0`` is the pre-pad size (for slicing the result back)."""
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), size


def fit_block(size: int, preferred: int) -> int:
    """Largest power-of-two shrink of ``preferred`` that divides ``size``.

    Kernels require the grid to tile the (padded) array exactly; this
    replaces the per-wrapper ``while size % b: b //= 2`` loops.  Always
    >= 1 for positive sizes (1 divides everything).
    """
    assert size > 0 and preferred > 0, (size, preferred)
    b = min(preferred, size)
    while size % b:
        b //= 2
    return max(b, 1)


# Softmax row-slab sizing: keep the live tile around 256k f32 elements
# (1 MB in + 1 MB out of ~16 MB VMEM) while widening the slab for short
# rows — at the paper's K=27 an 8-row slab would mean a grid step per
# 8 rows; 256k/32 lets thousands of rows share one kernel invocation.
_SM_TILE_ELEMS = 1 << 18


def _softmax_block_m(m: int, n: int) -> int:
    target = max(_sm.DEFAULT_BLOCK_M, min(1024, _SM_TILE_ELEMS // max(n, 1)))
    return fit_block(m, target)


def lut_gelu(x: jnp.ndarray, *, interp: bool = False,
             interpret: bool | None = None) -> jnp.ndarray:
    """Piecewise LUT GELU over any-shaped input."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    padded, m0 = pad_to_block(flat, 0, 8)
    padded, n0 = pad_to_block(padded, 1, 128)
    bm = fit_block(padded.shape[0], _gelu.DEFAULT_BLOCK_M)
    bn = fit_block(padded.shape[1], _gelu.DEFAULT_BLOCK_N)
    out = _gelu.lut_gelu_2d(padded, interp=interp, block_m=bm, block_n=bn,
                            interpret=_auto_interpret(interpret))
    return out[:m0, :n0].reshape(shape)


def lut_softmax(x: jnp.ndarray, *, fixed: bool = True,
                interpret: bool | None = None) -> jnp.ndarray:
    """LUT softmax along the last axis of any-shaped input.

    Padding rows (axis 0) are whole extra rows and are sliced away before
    returning — real rows never see padding lanes (the key axis is not
    padded), so the wrapper is exact with respect to the 2-D kernel.
    """
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    padded, m0 = pad_to_block(flat, 0, 8)
    bm = _softmax_block_m(padded.shape[0], padded.shape[1])
    out = _sm.lut_softmax_2d(padded, fixed=fixed, block_m=bm,
                             interpret=_auto_interpret(interpret))
    return out[:m0].reshape(shape)


def int8_matmul(x_int, w_int, *, x_exp: int | None = None,
                w_exp: int | None = None, out_exp: int | None = None,
                residual_bits: int = 32,
                interpret: bool | None = None) -> jnp.ndarray:
    """Quantised matmul -> dequantised f32 (contract matches ref.int8_matmul).

    Operands may be raw int arrays (+ explicit exponents) or stored
    ``quant.QTensor``s — int8 or nibble-packed int4 — whose exponents and
    per-channel refinements are read off the container: the full-integer
    pipeline runs the Pallas int8 x int8 -> int32 kernel directly on the
    bytes the Engine keeps resident.
    """
    from repro.core import quant as _q

    w_axis = None
    if isinstance(x_int, _q.QTensor):
        if x_int.axis_exponents is not None:
            # x's axis_exponents scale its LAST axis — the contraction
            # axis here — which cannot fold into a post-matmul rescale.
            raise NotImplementedError(
                "per-channel axis_exponents on the activation operand "
                "vary along the contraction axis; dequantise x instead")
        x_exp = x_int.exponent if x_exp is None else x_exp
        x_int = x_int.int_values()
    if isinstance(w_int, _q.QTensor):
        w_exp = w_int.exponent if w_exp is None else w_exp
        w_axis = w_int.axis_exponents
        w_int = w_int.int_values()
    assert x_exp is not None and w_exp is not None, \
        "raw int operands need explicit x_exp/w_exp"
    m, k = x_int.shape
    k2, n = w_int.shape
    xp, _ = pad_to_block(x_int, 0, 8)
    xp, _ = pad_to_block(xp, 1, 128)
    wp, _ = pad_to_block(w_int, 0, 128)
    wp, _ = pad_to_block(wp, 1, 128)
    acc_exp = x_exp + w_exp
    out_exp = acc_exp if out_exp is None else out_exp
    bm = fit_block(xp.shape[0], _mm.DEFAULT_BM)
    out = _mm.int8_matmul_raw(
        xp, wp, shift=acc_exp - out_exp, out_int16=(residual_bits == 16),
        block_m=bm, interpret=_auto_interpret(interpret))
    out = out[:m, :n].astype(jnp.float32) * (2.0 ** (-out_exp))
    if w_axis is not None:
        out = out * jnp.exp2(-w_axis.astype(jnp.float32))
    return out


def lut_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, use_lut: bool = True,
                  scale: float | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Flash attention with LUT-exp softmax; [B,H,L,D] GQA layout."""
    lq, lk = q.shape[2], k.shape[2]
    block_q = fit_block(lq, _attn.DEFAULT_BQ)
    block_k = fit_block(lk, _attn.DEFAULT_BK)
    return _attn.lut_attention(
        q, k, v, causal=causal, use_lut=use_lut, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))
