"""Pallas TPU kernel: piecewise LUT GELU (paper §VI, eq 13, Fig 7, ALU_GELU).

The paper's ALU_GELU is a scalar custom instruction backed by a 32-entry ROM
with identity/zero tails at +1.595 / -1.857.  TPU-native adaptation
(DESIGN.md §2): the 32-entry table is a VMEM-resident constant operand and
the piecewise select is vectorised across the 8x128 VPU lanes; the tails
become predicated selects.

Tiling: the input is viewed as [M, N]; each grid step owns a (block_m,
block_n) VMEM tile plus the whole (tiny) table.  Default tile 256x512 f32 =
512 kB in + 512 kB out, comfortably inside the ~16 MB v5e VMEM with double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lutlib

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 512


def _gelu_kernel(x_ref, tab_ref, o_ref, *, interp: bool):
    x = x_ref[...].astype(jnp.float32)
    tab = tab_ref[...]
    n = lutlib.N_GELU_ENTRIES
    scale = float(n - 1) / (lutlib.GELU_HI - lutlib.GELU_LO)
    t = (x - lutlib.GELU_LO) * scale
    if not interp:
        idx = jnp.clip(jnp.round(t).astype(jnp.int32), 0, n - 1)
        mid = jnp.take(tab, idx)
    else:
        tc = jnp.clip(t, 0.0, float(n - 1))
        i0 = jnp.clip(jnp.floor(tc).astype(jnp.int32), 0, n - 2)
        frac = tc - i0.astype(jnp.float32)
        mid = jnp.take(tab, i0) * (1.0 - frac) + jnp.take(tab, i0 + 1) * frac
    out = jnp.where(x > lutlib.GELU_HI, x,
                    jnp.where(x < lutlib.GELU_LO, 0.0, mid))
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interp", "block_m", "block_n", "interpret"))
def lut_gelu_2d(x: jnp.ndarray, *, interp: bool = False,
                block_m: int = DEFAULT_BLOCK_M, block_n: int = DEFAULT_BLOCK_N,
                interpret: bool = True) -> jnp.ndarray:
    """LUT GELU over a [M, N] array (padding/reshape handled by ops.py)."""
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    bank = lutlib.make_lut_bank()
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_gelu_kernel, interp=interp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((lutlib.N_GELU_ENTRIES,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, bank.gelu_f32)
