"""Pallas TPU kernel: flash-style attention with the paper's LUT softmax.

The paper's key numerical trick — max-normalised softmax (eq 10) so that
exp() has the bounded domain [0,10] servable by a 320-entry ROM — composes
*exactly* with online-softmax (flash) tiling: the running row max IS the
paper's max(x), and the rescale factor applied when the running max changes,
e^{-(m_new - m_old)}, is itself one more LUT_EXP lookup.  This kernel is the
TPU-native reading of the paper's ALU_EXP acceleration (DESIGN.md §2):
instead of one scalar ROM probe per element on a 50 MHz Ibex, the table sits
in VMEM and the probe vectorises over an 8x128 VREG tile, inside a kernel
that never materialises the [Lq, Lk] score matrix in HBM.

Layout: q [B, Hq, Lq, D], k/v [B, Hkv, Lk, D] (GQA: Hq % Hkv == 0).
Grid (B, Hq, Lq/bq, Lk/bk), KV innermost; VMEM scratch carries the running
(m, l, acc) across KV steps.  Causal masking is structural: masked lanes
contribute 0 to the numerator sum (no -inf arithmetic, no e^{-10} leak).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lut as lutlib

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG = -1e30


def _lut_exp_f32(z, tab):
    """e^{-z} for z >= 0 via the 320-entry ROM (eq 11), f32 carry."""
    idx = jnp.clip((z * lutlib.BINS_PER_UNIT).astype(jnp.int32),
                   0, lutlib.N_EXP_ENTRIES - 1)
    return jnp.take(tab, idx)


def _attn_kernel(q_ref, k_ref, v_ref, tab_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, n_kv: int, bq: int, bk: int,
                 lq: int, lk: int, use_lut: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        # query row r attends key c iff (global q pos) >= (global k pos),
        # with queries right-aligned against keys (decode-friendly).
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
            + (lk - lq)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = qpos >= kpos
        s = jnp.where(valid, s, _NEG)

    m_old = m_ref[...]                                # [bq, 1]
    m_tile = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_old, m_tile)
    z = jnp.clip(m_new - s, 0.0, lutlib.EXP_RANGE)
    if use_lut:
        p = _lut_exp_f32(z, tab_ref[...])
        alpha = _lut_exp_f32(jnp.clip(m_new - m_old, 0.0, lutlib.EXP_RANGE),
                             tab_ref[...])
    else:
        p = jnp.exp(-z)
        alpha = jnp.exp(-jnp.clip(m_new - m_old, 0.0, lutlib.EXP_RANGE))
    if causal:
        p = jnp.where(valid, p, 0.0)                  # structural mask
    else:
        p = jnp.where(s <= _NEG / 2, 0.0, p)

    v = v_ref[0, 0].astype(jnp.float32)               # [bk, D]
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "use_lut", "scale", "block_q", "block_k", "interpret"))
def lut_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, use_lut: bool = True,
                  scale: float | None = None,
                  block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                  interpret: bool = True) -> jnp.ndarray:
    """Flash attention with LUT-exp online softmax.  GQA-aware."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    bq = min(block_q, lq)
    bk = min(block_k, lk)
    assert lq % bq == 0 and lk % bk == 0, (lq, lk, bq, bk)
    n_kv = lk // bk
    grid = (b, hq, lq // bq, n_kv)
    bank = lutlib.make_lut_bank()

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, n_kv=n_kv, bq=bq, bk=bk,
        lq=lq, lk=lk, use_lut=use_lut)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, kk: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, i, kk, group=group: (bb, h // group, kk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, i, kk, group=group: (bb, h // group, kk, 0)),
            pl.BlockSpec((lutlib.N_EXP_ENTRIES,), lambda bb, h, i, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, kk: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bank.exp_f32)
