"""Pallas TPU kernel: row softmax via the paper's LUT pipeline (§VI, eq 10-12).

Fixed-point path (`fixed=True`, the "+Hardware" Table IX configuration):
  per row r:   z_i  = clip(max_j x_rj - x_ri, 0, 10)        (eq 10)
               n_i  = LUT_EXP[z_i * 32]        (Q8.24, ALU_EXP)
               s    = sum_i (n_i >> pre)       (int32-safe accumulate)
               inv  = reciprocal_q24(s) >> pre (ALU_INVERT + range reduce)
               y_i  = fixed_mul(n_i, inv)      (Q8.24 multiply)
matching `repro.core.approx.softmax_lut(fixed=True)` bit-for-bit.

Float path (`fixed=False`): LUT_EXP gather in f32 + true division — the
"LUT softmax, float carry" intermediate the paper describes for the
quantised-but-unaccelerated model (Table IX column 3).

Tiling: one grid step owns a (block_m, N) row-slab so the row reduction
stays on-chip; the 320-entry tables ride along as whole-array VMEM operands.
VMEM at N=32k, bm=8: 8*32768*4 = 1 MB in + 1 MB out (+LUTs) — fine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import fixedpoint as fxp
from repro.core import lut as lutlib

DEFAULT_BLOCK_M = 8


def _reciprocal_q24_body(s_q, inv_tab):
    """reciprocal_q24 (lut.py) inlined for the kernel body (same math)."""
    t = fxp.ilog2(s_q) - fxp.FRAC_BITS
    tp = jnp.maximum(t, 0)
    tn = jnp.maximum(-t, 0)
    m = ((s_q >> tp) << tn).astype(jnp.int32)
    shift = fxp.FRAC_BITS - int(np.log2(lutlib.BINS_PER_UNIT))
    idx = jnp.clip((m >> shift) - 1, 0, lutlib.N_EXP_ENTRIES - 1)
    inv_m = jnp.take(inv_tab, idx)
    limit = jnp.int32(2**31 - 1) >> tn
    return jnp.where(t >= 0, inv_m >> tp,
                     jnp.where(inv_m > limit, jnp.int32(2**31 - 1),
                               inv_m << tn)).astype(jnp.int32)


def _softmax_kernel_fixed(x_ref, exp_tab_ref, inv_tab_ref, o_ref, *, pre: int):
    x = x_ref[...].astype(jnp.float32)
    exp_tab = exp_tab_ref[...]
    inv_tab = inv_tab_ref[...]
    z = jnp.clip(jnp.max(x, axis=-1, keepdims=True) - x, 0.0, lutlib.EXP_RANGE)
    z_q = jnp.round(z * float(fxp.ONE)).astype(jnp.int32)        # ALU_TO_FIXED
    shift = fxp.FRAC_BITS - int(np.log2(lutlib.BINS_PER_UNIT))
    idx = jnp.clip(z_q >> shift, 0, lutlib.N_EXP_ENTRIES - 1)
    num_q = jnp.take(exp_tab, idx)                               # ALU_EXP
    s_q = jnp.sum(num_q >> pre, axis=-1, keepdims=True)
    inv_q = _reciprocal_q24_body(s_q, inv_tab) >> pre            # ALU_INVERT
    out_q = fxp.fixed_mul(num_q, inv_q, nonneg=True)
    o_ref[...] = fxp.to_float(out_q).astype(o_ref.dtype)        # ALU_TO_FLOAT


def _softmax_kernel_float(x_ref, exp_tab_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    exp_tab = exp_tab_ref[...]
    z = jnp.clip(jnp.max(x, axis=-1, keepdims=True) - x, 0.0, lutlib.EXP_RANGE)
    idx = jnp.clip((z * lutlib.BINS_PER_UNIT).astype(jnp.int32),
                   0, lutlib.N_EXP_ENTRIES - 1)
    num = jnp.take(exp_tab, idx)
    o_ref[...] = (num / jnp.sum(num, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fixed", "block_m", "interpret"))
def lut_softmax_2d(x: jnp.ndarray, *, fixed: bool = True,
                   block_m: int = DEFAULT_BLOCK_M,
                   interpret: bool = True) -> jnp.ndarray:
    """LUT softmax along the last axis of a [M, N] array."""
    m, n = x.shape
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    bank = lutlib.make_lut_bank()
    pre = max(0, int(np.ceil(np.log2(max(n, 1)))) - 6)
    grid = (m // bm,)
    row_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    tab_spec = pl.BlockSpec((lutlib.N_EXP_ENTRIES,), lambda i: (0,))
    if fixed:
        return pl.pallas_call(
            functools.partial(_softmax_kernel_fixed, pre=pre),
            grid=grid,
            in_specs=[row_spec, tab_spec, tab_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=interpret,
        )(x, bank.exp_q24, bank.inv_q24)
    return pl.pallas_call(
        _softmax_kernel_float,
        grid=grid,
        in_specs=[row_spec, tab_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, bank.exp_f32)
