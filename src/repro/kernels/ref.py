"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the bit-level specification its kernel is tested against
(tests/kernels/*): same LUT contents, same index math, same accumulation
widths — only the tiling differs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx, lut as lutlib, quant


def lut_softmax(x: jnp.ndarray, *, fixed: bool = True,
                range_reduce: bool = True) -> jnp.ndarray:
    """Row softmax over the last axis via the paper's LUT pipeline."""
    return approx.softmax_lut(x, axis=-1, fixed=fixed, range_reduce=range_reduce)


def lut_gelu(x: jnp.ndarray, *, interp: bool = False) -> jnp.ndarray:
    return approx.gelu_lut(x, interp=interp)


def int8_matmul(x_int: jnp.ndarray, w_int: jnp.ndarray, *, x_exp: int,
                w_exp: int, out_exp: int | None = None,
                residual_bits: int = 32) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 accumulate -> shift-rescale (paper eq 9 epilogue).

    Returns float32 dequantised output (the framework-facing contract).
    """
    q = quant.qmatmul(quant.QTensor(x_int, x_exp), quant.QTensor(w_int, w_exp),
                      out_exponent=out_exp, residual_bits=residual_bits)
    return q.dequantize()


def masked_lut_softmax(s: jnp.ndarray, mask: jnp.ndarray | None) -> jnp.ndarray:
    """LUT softmax with *structural* masking: masked scores never enter the
    numerator sum (mirrors the C pipeline, which only computes valid
    entries) — avoids the e^{-10} clip leak that -inf masking would cause.
    """
    bank = lutlib.make_lut_bank()
    s = s.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    sm = s if mask is None else jnp.where(mask, s, neg)
    m = jnp.max(sm, axis=-1, keepdims=True)
    z = jnp.clip(m - s, 0.0, lutlib.EXP_RANGE)
    num = jnp.take(jnp.asarray(bank.exp_f32),
                   jnp.clip((z * lutlib.BINS_PER_UNIT).astype(jnp.int32),
                            0, lutlib.N_EXP_ENTRIES - 1))
    if mask is not None:
        num = jnp.where(mask, num, 0.0)
    return num / jnp.sum(num, axis=-1, keepdims=True)


def lut_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, softmax_mode: str = "lut",
                  scale: float | None = None) -> jnp.ndarray:
    """Reference scaled-dot-product attention with LUT softmax (eq 1 + eq 10).

    q: [B, Hq, Lq, D], k/v: [B, Hkv, Lk, D] with Hq % Hkv == 0 (GQA).
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(b, hkv, group, lq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * scale
    lk = k.shape[2]
    mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq) if causal else None
    if softmax_mode == "exact":
        sm = s if mask is None else jnp.where(mask, s, jnp.finfo(jnp.float32).min)
        p = jax.nn.softmax(sm, axis=-1)
    else:
        p = masked_lut_softmax(s, mask)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, lq, d).astype(q.dtype)
