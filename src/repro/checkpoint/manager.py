"""Fault-tolerant checkpointing: sharded npz + manifest, atomic, async.

Design (scales to multi-host; exercised single-process here):
  step_000100.tmp-<nonce>/         <- written first
    manifest.json                  <- pytree structure, shapes, dtypes
    shard_<i>.npz                  <- leaf arrays (per-host addressable data)
  step_000100/                     <- atomic rename on completion
A checkpoint is valid iff the rename completed -> a crash mid-save never
corrupts the restore path (restore picks the newest *complete* step).

Restore is resharding-aware: arrays are loaded host-side and device_put
against the *current* mesh's NamedShardings, so a job may restart on a
different mesh shape (elastic restart, tested in tests/test_checkpoint.py).

Packed quantised trees (``core.quant.QTensor`` leaves — int8 bodies,
nibble-packed uint8 at ``bits<=4``, int8 axis exponents) round-trip
WITHOUT upcasting: leaves are written at their stored dtypes and the
static exponent/bits/logical_shape metadata rides the pytree structure
of the restore target, so a checkpointed export artifact is byte-for-byte
the flashable ROM image (tests/test_train_infra.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import uuid

import jax
import numpy as np

_SENTINEL = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True):
    """Serialise a pytree.  Returns the thread when blocking=False."""
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    payload = (ckpt_dir, step, host_leaves, jax.tree.map(lambda _: 0, tree))

    def _write():
        d_final = os.path.join(ckpt_dir, f"step_{step:08d}")
        d_tmp = d_final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(d_tmp, exist_ok=True)
        np.savez(os.path.join(d_tmp, "shard_0.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "treedef": str(treedef),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
        }
        with open(os.path.join(d_tmp, _SENTINEL), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d_final):
            shutil.rmtree(d_final)
        os.rename(d_tmp, d_final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def is_complete(ckpt_dir: str, step: int) -> bool:
    """True iff the step's directory is a fully materialised checkpoint
    (manifest parses, payload shard present) — what a watcher may load."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(d, _SENTINEL)) as f:
            json.load(f)
    except (OSError, ValueError):
        return False
    return os.path.exists(os.path.join(d, "shard_0.npz"))


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a *complete* checkpoint.

    Built for being polled while writers race (``cell.hotswap``): the
    atomic tmp+rename protocol means anything this returns is loadable,
    and anything else in the directory — in-flight ``.tmp-*`` dirs,
    unparsable names, manifest-less or payload-less stragglers from an
    external partial copy — is SKIPPED, never an exception.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or ".tmp" in name:
            continue
        try:
            step = int(name.split("_")[1])
        except ValueError:          # step_garbage, step_ etc.
            continue
        if is_complete(ckpt_dir, step):
            steps.append(step)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Load into the structure of ``target_tree``; device_put against
    ``shardings`` (same-structure NamedSharding tree) when given —
    this is the elastic-restart path (mesh may differ from save time)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    leaves, treedef = _flatten(target_tree)
    loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for a, ref in zip(loaded, leaves):
        assert tuple(a.shape) == tuple(ref.shape), (a.shape, ref.shape)
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        loaded = [jax.device_put(a.astype(ref.dtype), s)
                  for a, ref, s in zip(loaded, leaves, shard_leaves)]
    else:
        loaded = [jax.numpy.asarray(a).astype(ref.dtype)
                  for a, ref in zip(loaded, leaves)]
    return jax.tree.unflatten(treedef, loaded)
