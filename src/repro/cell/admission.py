"""Admission control + backpressure for a serving cell's stream lanes.

A cell has a fixed lane budget; offered streams beyond it wait in a
BOUNDED queue.  Overload is handled in escalating stages, every decision
surfaced as a ``cell_admission_total{decision=...}`` counter:

1. **admit** — a token bucket (``rate`` admits/s, ``burst`` capacity)
   smooths arrival spikes; within rate and queue bounds, the stream is
   queued for the next free lane.
2. **degrade** — before anything is refused, the CELL degrades: when the
   queue backs up (or queue wait approaches the deadline), admitted
   streams are served at ``degraded_chunk_hops`` hops per engine step.
   A wider chunk amortises the per-step encoder cost over more audio —
   the real-time budget per step scales with ``chunk_hops`` while the
   step cost grows sub-linearly (benchmarks/stream_bench.py), so the
   cell trades detection latency for throughput instead of shedding.
   The degrade is cell-wide (one batch has one chunk width).
3. **reject** — a full queue, an exhausted token bucket, or a stream
   whose queue wait exceeded ``deadline_ms`` is shed.  Rejection happens
   strictly BEFORE any audio is ingested, so the cell's zero-dropped-hop
   accounting (``cell_hops_total`` vs offered source hops) is unaffected
   by shedding: an admitted stream is always served completely.

Time is injectable (``clock``) so every decision is unit-testable
without sleeping.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    max_queue: int = 64             # bounded wait queue (lanes excluded)
    rate: float = math.inf          # token bucket: admissions per second
    burst: int = 16                 # bucket capacity
    deadline_ms: Optional[float] = None   # max queue wait before shedding
    degrade_queue: int = 8          # queue depth that triggers degrade
    degraded_chunk_hops: int = 4    # hops per engine step when degraded


@dataclasses.dataclass
class Decision:
    admitted: bool
    reason: str                     # "admit" | "queue_full" | "rate" | "deadline"


class AdmissionController:
    """Bounded queue + token bucket + deadline shedding + degrade signal."""

    def __init__(self, cfg: AdmissionConfig = AdmissionConfig(),
                 metrics=None, clock=time.monotonic):
        self.cfg = cfg
        self.metrics = metrics
        self._clock = clock
        self._queue: collections.deque = collections.deque()  # (item, t_in)
        self._tokens = float(cfg.burst)
        self._t_last = clock()
        self.degraded = False

    # -- token bucket ------------------------------------------------------

    def _refill(self, now: float) -> None:
        if math.isinf(self.cfg.rate):
            self._tokens = float(self.cfg.burst)
        else:
            self._tokens = min(float(self.cfg.burst),
                               self._tokens
                               + (now - self._t_last) * self.cfg.rate)
        self._t_last = now

    # -- intake ------------------------------------------------------------

    def offer(self, item: Any) -> Decision:
        """Admit ``item`` into the wait queue, or reject with a reason."""
        now = self._clock()
        self._refill(now)
        if len(self._queue) >= self.cfg.max_queue:
            return self._reject("queue_full")
        if self._tokens < 1.0:
            return self._reject("rate")
        self._tokens -= 1.0
        self._queue.append((item, now))
        if self.metrics is not None:
            self.metrics.admitted.inc()
            self.metrics.queue_depth.set(len(self._queue))
        return Decision(True, "admit")

    def _reject(self, reason: str) -> Decision:
        if self.metrics is not None:
            self.metrics.rejected.inc()
        return Decision(False, reason)

    # -- hand-off to lanes -------------------------------------------------

    def pop(self) -> Optional[Any]:
        """Next admitted item for a free lane; sheds items whose queue wait
        blew the deadline (counted as rejections — they never served)."""
        now = self._clock()
        dl = self.cfg.deadline_ms
        while self._queue:
            item, t_in = self._queue.popleft()
            if dl is not None and (now - t_in) * 1e3 > dl:
                self._reject("deadline")
                continue
            if self.metrics is not None:
                self.metrics.queue_depth.set(len(self._queue))
            return item
        if self.metrics is not None:
            self.metrics.queue_depth.set(0)
        return None

    def __len__(self) -> int:
        return len(self._queue)

    # -- degrade signal ----------------------------------------------------

    def chunk_hops(self) -> int:
        """Hops per engine step the cell should run at right now.

        Degrades (cell-wide) when the queue is past ``degrade_queue`` or
        the OLDEST waiter has used half its deadline; recovers hysteresis-
        free once the queue drains (an empty queue serves at chunk 1).
        """
        cfg = self.cfg
        backed_up = len(self._queue) > cfg.degrade_queue
        if not backed_up and cfg.deadline_ms is not None and self._queue:
            wait_ms = (self._clock() - self._queue[0][1]) * 1e3
            backed_up = wait_ms > cfg.deadline_ms / 2
        if backed_up and not self.degraded:
            if self.metrics is not None:
                self.metrics.degraded.inc()
        self.degraded = backed_up
        return cfg.degraded_chunk_hops if backed_up else 1
