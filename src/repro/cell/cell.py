"""ServeCell: everything between a request and an Engine, on one host.

One cell owns, per host of the serving fleet:

* a swap-safe :class:`runtime.EngineHandle` (``cell.hotswap`` replaces
  the Engine under it without touching lane state),
* a pool of ``slots`` batch lanes — streaming-KWS lanes
  (:class:`StreamLanes`, the fused engine+detector hop) or LM request
  lanes (:class:`cell.scheduler.LMScheduler`, continuous batching),
* an :class:`cell.admission.AdmissionController` in front of the lanes,
* the ``cell_*`` metric bundle on the run's telemetry registry,
* optionally a :class:`cell.hotswap.CheckpointWatcher` on a directory
  where training publishes packed artifacts.

Entering the cell (``with cell:``) activates the host mesh and the
``dist.ctx`` data-parallel context, so every activation the lanes push
through ``stream_step`` / ``decode_step`` is sharded per-lane over the
mesh's DP axes (exact no-op on a single device).  Multi-host: run one
cell per host over that host's mesh slice; cells share nothing but the
checkpoint directory, which is how new weights propagate.

Both serve launchers (``launch/serve.py``, ``launch/stream_serve.py``)
are thin CLIs over this class.
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro import telemetry
from repro.cell import admission as admission_mod
from repro.cell import hotswap as hotswap_mod
from repro.cell import pipeline as pipeline_mod
from repro.cell import scheduler as scheduler_mod
from repro.dist import ctx
from repro.launch import mesh as meshlib
from repro.stream import detector as det
from repro.stream import engine as stream_engine
from repro.telemetry import flight as flight_mod
from repro.telemetry.cell import make_cell_metrics


class ServeCell:
    """One host's serving cell: EngineHandle + lanes + admission + swap."""

    def __init__(self, engine, *, slots: int,
                 registry: Optional[telemetry.Registry] = None,
                 admission: Optional[admission_mod.AdmissionConfig] = None,
                 watch_dir: Optional[str] = None,
                 watch_like: Any = None,
                 probe: Any = None,
                 flight: Any = None,
                 mesh=None, poll_s: float = 0.5):
        self.handle = engine if isinstance(engine, runtime.EngineHandle) \
            else runtime.EngineHandle(engine)
        self.slots = slots
        self.metrics = make_cell_metrics(registry if registry is not None
                                         else telemetry.default_registry())
        self.admission = admission_mod.AdmissionController(
            admission or admission_mod.AdmissionConfig(),
            metrics=self.metrics)
        self.watcher = None
        self._watch_like, self._probe = watch_like, probe
        if watch_dir is not None:
            assert watch_like is not None and probe is not None, \
                "a watching cell needs a restore template and a probe batch"
            self.watcher = hotswap_mod.CheckpointWatcher(watch_dir,
                                                         poll_s=poll_s)
        self.mesh = meshlib.make_host_mesh() if mesh is None else mesh
        self.metrics.engine_generation.set(self.handle.generation)
        # black box: ``flight`` is a FlightRecorder, a FlightConfig, or
        # True for defaults; every lane hop feeds it (StreamLanes.hop)
        # and swap attempts re-check its triggers (maybe_swap).
        if flight is True:
            flight = flight_mod.FlightConfig()
        if isinstance(flight, flight_mod.FlightConfig):
            flight = flight_mod.FlightRecorder(self.metrics, flight)
        self.flight: Optional[flight_mod.FlightRecorder] = flight
        self._stack = None

    @property
    def engine(self) -> runtime.Engine:
        return self.handle.engine

    # -- mesh activation ---------------------------------------------------

    def __enter__(self) -> "ServeCell":
        assert self._stack is None, "cell already active"
        self._stack = contextlib.ExitStack()
        self._stack.enter_context(self.mesh)
        self._stack.enter_context(
            ctx.mesh_context(meshlib.dp_axes(self.mesh)))
        return self

    def __exit__(self, *exc) -> None:
        stack, self._stack = self._stack, None
        stack.close()

    # -- lane pools --------------------------------------------------------

    def stream_lanes(self, fcfg, dcfg, *, chunk_hops: int = 1,
                     keep_features: bool = False,
                     pipelined: bool = False,
                     feature_ingest: bool = False) -> "StreamLanes":
        return StreamLanes(self, fcfg, dcfg, chunk_hops=chunk_hops,
                           keep_features=keep_features, pipelined=pipelined,
                           feature_ingest=feature_ingest)

    def lm_scheduler(self, *, max_len: int, eos_id: Optional[int] = None,
                     prefill_len: Optional[int] = None
                     ) -> scheduler_mod.LMScheduler:
        return scheduler_mod.LMScheduler(
            self.handle, slots=self.slots, max_len=max_len, eos_id=eos_id,
            prefill_len=prefill_len, metrics=self.metrics)

    # -- checkpoint hot-swap ----------------------------------------------

    def maybe_swap(self) -> bool:
        """One watch tick (call between hops): swap in a freshly published
        complete checkpoint, if any.  Never drops a lane — see
        ``cell.hotswap``."""
        if self.watcher is None:
            return False
        swapped = hotswap_mod.poll_and_swap(
            self.handle, self.watcher, self._watch_like, self._probe,
            metrics=self.metrics)
        if self.flight is not None:
            # a probe-parity failure bumps swap_failures; re-check the
            # triggers now instead of waiting for the next hop
            self.flight.check()
        return swapped


class StreamLanes:
    """``slots`` hop-synchronous audio lanes under one cell.

    Owns the engine + detector state pytrees and the per-lane lifecycle:
    ``join(lane)`` zeroes BOTH the stream state and the detector state of
    the lane (a recycled lane must not inherit the previous stream's
    hysteresis/refractory/warm-up — stream.detector), ``hop(chunk)``
    advances every lane by ``chunk_hops`` hops through the fused
    engine+detector step (or the split featurise/encode pipeline when
    ``pipelined``), ``evict(lane)`` frees it.

    Ingest modes: by default ``hop`` takes raw audio [B, chunk_samples]
    and the cell runs the MFCC frontend; with ``feature_ingest=True`` it
    takes pre-featurised frames [B, chunk_hops, F] — the deployment
    where edge devices featurise next to the microphone (as the paper's
    MCU target does) and the cell serves the encoder+detector.  Frames
    produced by ``features.frontend_push`` yield bit-identical scores on
    either path (tests/test_cell.py).

    Hop accounting: ``cell_hops_total`` counts hops ingested per ACTIVE
    lane — the quantity the soak reconciles against the offered source
    hops to assert zero drops across churn and hot-swaps.
    """

    def __init__(self, cell: ServeCell, fcfg, dcfg, *, chunk_hops: int = 1,
                 keep_features: bool = False, pipelined: bool = False,
                 feature_ingest: bool = False):
        eng = cell.engine
        assert eng.exec_cfg.family == "kwt", \
            "stream lanes drive the KWT family"
        assert not (pipelined and feature_ingest), \
            "feature ingest has no featurise stage to pipeline"
        self.cell, self.fcfg, self.dcfg = cell, fcfg, dcfg
        self.chunk_hops = chunk_hops
        self.feature_ingest = feature_ingest
        self.active = np.zeros(cell.slots, bool)
        cfg = eng.exec_cfg
        self.state = stream_engine.init_stream_state(
            cfg, fcfg, cell.slots, keep_features=keep_features)
        self.dstate = det.detector_init(dcfg, cell.slots)
        self._pipe = pipeline_mod.HopPipeline(
            cell.handle, fcfg, keep_features=keep_features, donate=False) \
            if pipelined else None

        def joint(params, state, dstate, chunk):
            if feature_ingest:
                state, logits = stream_engine.stream_step_frames(
                    params, state, chunk, cfg)
            else:
                state, logits = stream_engine.stream_step(params, state,
                                                          chunk, cfg, fcfg)
            dstate, events = det.detector_step(
                dstate, stream_engine.posteriors(logits), dcfg,
                warm=stream_engine.warm(state))
            return state, dstate, events

        self._joint = None if pipelined else jax.jit(joint)
        if cell.flight is not None and cell.flight.stage_weights is None:
            # static fallback attribution for flight dumps: the cost
            # model's roofline-weighted stage split of exactly this hop
            # program (lazy: traced only if a dump ever happens)
            def _weights(eng=eng, fcfg=fcfg, k=chunk_hops,
                         fi=feature_ingest):
                from repro import perf
                rep = perf.stream_hop_cost(eng, fcfg, batch=1,
                                           chunk_hops=k, feature_ingest=fi)
                return rep.stage_weights(perf.host_machine())
            cell.flight.stage_weights = _weights
        self._det = jax.jit(lambda ds, lg, warm: det.detector_step(
            ds, stream_engine.posteriors(lg), dcfg, warm=warm)) \
            if pipelined else None
        self._reset = jax.jit(lambda s, ds, lane: (
            stream_engine.reset_lane(s, lane),
            det.detector_reset_lane(ds, lane)))

    @property
    def chunk_samples(self) -> int:
        return self.chunk_hops * self.fcfg.hop_len

    def set_chunk_hops(self, k: int) -> None:
        """Adopt the admission controller's degrade signal.  Lane state is
        hop-count agnostic (rings advance per frame), so the width can
        change between steps; a new width compiles its own step variant."""
        self.chunk_hops = int(k)

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def free_lanes(self) -> list[int]:
        return [i for i in range(len(self.active)) if not self.active[i]]

    def join(self, lane: int) -> None:
        """Claim a lane for a new stream: zero its ring/frontend/detector
        state so nothing leaks from the previous occupant."""
        assert not self.active[lane], f"lane {lane} is occupied"
        self.state, self.dstate = self._reset(self.state, self.dstate, lane)
        self.active[lane] = True
        m = self.cell.metrics
        m.joins.inc()
        m.occupancy.set(self.n_active / len(self.active))

    def evict(self, lane: int) -> None:
        assert self.active[lane], f"lane {lane} is already free"
        self.active[lane] = False
        m = self.cell.metrics
        m.evictions.inc()
        m.occupancy.set(self.n_active / len(self.active))

    def hop(self, chunk, ingest=None) -> dict:
        """Advance all lanes by ``chunk`` — raw audio
        [slots, chunk_samples], or pre-featurised frames
        [slots, chunk_hops, F] under ``feature_ingest``; returns
        the detector events ``{"fired": [B], "score": [B], ...}`` (host
        numpy — the per-hop sync point, as in the pre-cell server).

        ``ingest`` ([slots] ints) overrides the per-lane hop accounting
        for steps whose trailing chunk is zero-padded past a stream's
        end (a degraded-width step need not divide the stream length);
        default: ``chunk_hops`` for every active lane."""
        m = self.cell.metrics
        t0 = time.perf_counter()
        chunk = jnp.asarray(chunk)
        p = self.cell.handle.live_params()
        if self._joint is not None:
            self.state, self.dstate, events = self._joint(
                p, self.state, self.dstate, chunk)
        else:
            self.state, window = self._pipe._feat(p, self.state, chunk)
            logits = self._pipe._enc(p, window)
            warm = self.state["embed"]["count"] >= \
                stream_engine.window_frames(self.cell.engine.exec_cfg)
            self.dstate, events = self._det(self.dstate, logits, warm)
        events = jax.tree.map(np.asarray, jax.block_until_ready(events))
        dur_ms = 1e3 * (time.perf_counter() - t0)
        m.hop_ms.observe(dur_ms)
        m.hops.inc(int(np.sum(ingest)) if ingest is not None
                   else self.chunk_hops * self.n_active)
        if self.cell.flight is not None:
            self.cell.flight.record_hop(dur_ms)
        return events
