"""repro.cell — the serving cell: everything between a request and an
Engine.

* :mod:`repro.cell.scheduler` — continuous batching for LM lanes:
  per-lane decode depth, in-flight join via fresh-prefill + per-lane
  state merge, per-slot EOS/evict, no drain barrier.
* :mod:`repro.cell.admission` — bounded queues, token-bucket rate
  limiting, deadline shedding, and the cell-wide chunk-hops degrade
  stage, every decision a ``cell_admission_total`` counter.
* :mod:`repro.cell.pipeline`  — the featurise/encode split of the
  streaming hop with async double-buffered dispatch, bit-identical to
  the fused ``stream_step`` per backend.
* :mod:`repro.cell.hotswap`   — checkpoint-watching hot-swap: load a
  freshly published packed artifact, warm it, gate it on probe-logit
  parity, install it atomically without dropping lanes.
* :mod:`repro.cell.cell`      — :class:`ServeCell` composing the above
  over one host's ``dist.ctx`` mesh; both serve launchers are thin CLIs
  over it.

See README §repro.cell.
"""

from repro.cell.admission import (AdmissionConfig, AdmissionController,
                                  Decision)
from repro.cell.cell import ServeCell, StreamLanes
from repro.cell.hotswap import (CheckpointWatcher, SwapRejected, hot_swap,
                                poll_and_swap)
from repro.cell.pipeline import HopPipeline
from repro.cell.scheduler import LMScheduler, Request, TokenEvent

__all__ = ["AdmissionConfig", "AdmissionController", "CheckpointWatcher",
           "Decision", "HopPipeline", "LMScheduler", "Request", "ServeCell",
           "StreamLanes", "SwapRejected", "TokenEvent", "hot_swap",
           "poll_and_swap"]
