"""Continuous batching for LM serving: in-flight join, per-lane evict.

``launch/serve.py``'s slot loop refilled by re-running ``prefill`` over
the WHOLE batch from a re-initialised decode state — a global drain
barrier that also wiped resident lanes' KV caches mid-request.  The
scheduler replaces it with true continuous batching:

* the decode state's ``index`` is a per-lane [B] vector
  (``models.transformer``: cache writes scatter at ``[lane, idx[lane]]``,
  RoPE positions and validity bounds are per-lane), so every lane decodes
  at its own depth;
* joiners prefill into a FRESH decode state (ordinary scalar-index
  prefill of the right-padded prompt minus its last token) which is then
  merged per-lane into the live state
  (``transformer.merge_decode_state``) — resident lanes never stop
  decoding and their caches are untouched;
* the first ``decode_step`` after a join feeds the prompt's LAST token,
  writing its KV at slot ``len-1`` under the lane's own position — from
  then on the lane is indistinguishable from one that prefilled alone.

Because positions, cache slots and validity masks are all per-lane, a
request's greedy token sequence depends only on its prompt, the batch
width and the prefill pad width — NOT on what the other lanes are doing.
With a fixed ``prefill_len`` the schedule is invisible to outputs:
submitting the same requests in any order yields bit-identical tokens
per request (tests/test_cell.py).

Families: dense / moe (KV-cache attention, where pad keys can be masked
after the fact).  Recurrences (rwkv, hybrid's ring+SSM) fold pad tokens
irreversibly into their state under any batched padding and keep the
drain-batch serve path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt tokens + a generation budget."""

    rid: Any
    prompt: np.ndarray          # [L] int32, L >= 1
    max_new: int                # generation budget (tokens)


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One decoded token for one request (``done`` on the last one)."""

    rid: Any
    token: int
    done: bool = False
    reason: str = ""            # "eos" | "len" when done


def _bucket(n: int) -> int:
    """Next power of two >= n: bounds prefill retraces to O(log max_len)."""
    b = 1
    while b < n:
        b *= 2
    return b


class LMScheduler:
    """A fixed pool of ``slots`` decode lanes with in-flight join/evict.

    Drive with ``submit`` + repeated ``step``; each ``step`` joins
    waiting requests into free lanes (one batched fresh prefill, no
    drain), advances EVERY lane one greedy token, and evicts lanes whose
    request hit EOS or its budget.  Evicted lanes keep decoding garbage
    until re-joined (the batch shape is static); their outputs are
    discarded and their per-lane index is parked at 0 so cache scatters
    stay in bounds.

    ``engine`` is a ``runtime.Engine`` or a swap-safe
    ``runtime.EngineHandle`` — the scheduler reads the live engine each
    step, so a hot-swap between steps changes params only (lane caches
    and positions survive; exec-config compatibility is enforced by
    ``EngineHandle.swap``).
    """

    def __init__(self, engine, *, slots: int, max_len: int,
                 eos_id: Optional[int] = None,
                 prefill_len: Optional[int] = None, metrics=None):
        cfg = self._engine(engine).exec_cfg
        assert cfg.family in ("dense", "moe"), \
            f"continuous batching covers dense/moe, not {cfg.family}"
        self._eng_ref = engine
        self.slots, self.max_len, self.eos_id = slots, max_len, eos_id
        self.prefill_len = prefill_len      # None -> per-group pow2 bucket
        self.metrics = metrics
        self._merge = jax.jit(transformer.merge_decode_state)
        self.queue: list[Request] = []
        self.active: list[Optional[Request]] = [None] * slots
        self._remaining = np.zeros(slots, np.int64)
        self.state = self._engine(engine).init_decode_state(slots, max_len)
        # per-lane depth from step one (scalar would retrace on first merge)
        self.state["index"] = jnp.zeros((slots,), jnp.int32)
        self._cur = jnp.zeros((slots,), jnp.int32)

    @staticmethod
    def _engine(ref):
        return ref.engine if hasattr(ref, "engine") else ref

    @property
    def engine(self):
        return self._engine(self._eng_ref)

    # -- request intake ----------------------------------------------------

    def submit(self, rid, prompt, max_new: int) -> None:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert 1 <= prompt.size and prompt.size - 1 + max_new <= self.max_len, \
            (prompt.size, max_new, self.max_len)
        self.queue.append(Request(rid, prompt, int(max_new)))
        if self.metrics is not None:
            self.metrics.queue_depth.set(len(self.queue))

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.active if r is not None)

    def idle(self) -> bool:
        return self.n_active == 0 and not self.queue

    # -- one scheduler tick ------------------------------------------------

    def step(self) -> list[TokenEvent]:
        """Join waiting requests, decode one token on every lane, evict."""
        if self.idle():
            return []
        self._join()
        eng, met = self.engine, self.metrics
        t0 = time.perf_counter()
        logits, self.state = eng.decode_step(self._cur, self.state)
        self._cur = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = np.asarray(self._cur)
        if met is not None:
            met.decode_ms.observe(1e3 * (time.perf_counter() - t0))
            met.tokens.inc(self.n_active)
        events, evicted = [], []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self._remaining[i] -= 1
            is_eos = self.eos_id is not None and int(toks[i]) == self.eos_id
            done = is_eos or self._remaining[i] <= 0
            events.append(TokenEvent(req.rid, int(toks[i]), done,
                                     ("eos" if is_eos else "len")
                                     if done else ""))
            if done:
                self.active[i] = None
                evicted.append(i)
        if evicted:
            # park freed lanes at depth 0: they keep decoding (static batch)
            # but their cache scatters must stay in bounds until re-joined
            park = np.zeros(self.slots, bool)
            park[evicted] = True
            self.state["index"] = jnp.where(jnp.asarray(park), 0,
                                            self.state["index"])
            if met is not None:
                met.evictions.inc(len(evicted))
        if met is not None:
            met.occupancy.set(self.n_active / self.slots)
        return events

    def run(self) -> dict:
        """Drain: step until idle, tokens grouped per request id."""
        out: dict = {}
        while not self.idle():
            for ev in self.step():
                out.setdefault(ev.rid, []).append(ev.token)
        return out

    # -- the join half -----------------------------------------------------

    def _join(self) -> None:
        free = [i for i in range(self.slots) if self.active[i] is None]
        joins = list(zip(free, [self.queue.pop(0)
                                for _ in free[:len(self.queue)]]))
        if not joins:
            return
        eng, met = self.engine, self.metrics
        B = self.slots
        # right-pad prompts MINUS their last token; the first decode_step
        # feeds that token, so real token j always sits at cache slot j
        # with position j and pad keys are masked by the per-lane validity
        # bound — lane results don't depend on co-joiners' prompts.
        lens = {i: len(r.prompt) for i, r in joins}
        plen = self.prefill_len or _bucket(max(max(lens.values()) - 1, 1))
        assert plen >= max(lens.values()) - 1, \
            f"prefill_len={plen} shorter than a submitted prompt"
        toks = np.zeros((B, plen), np.int32)
        cur, idx = np.asarray(self._cur).copy(), \
            np.asarray(self.state["index"]).copy()
        mask = np.zeros(B, bool)
        for i, req in joins:
            toks[i, :lens[i] - 1] = req.prompt[:-1]
            cur[i] = req.prompt[-1]
            idx[i] = lens[i] - 1
            mask[i] = True
            self.active[i] = req
            self._remaining[i] = req.max_new
        t0 = time.perf_counter()
        fresh = eng.init_decode_state(B, self.max_len)
        _, fresh = eng.prefill(jnp.asarray(toks), fresh)
        merged = self._merge(self.state, fresh, jnp.asarray(mask))
        merged["index"] = jnp.asarray(idx, jnp.int32)
        self.state = merged
        self._cur = jnp.asarray(cur)
        if met is not None:
            met.prefill_ms.observe(1e3 * (time.perf_counter() - t0))
            met.joins.inc(len(joins))
            met.prefill_tokens.inc(int(sum(lens.values())))
            met.queue_depth.set(len(self.queue))
