"""Async double-buffered hop pipelining: featurise t+1 under encode t.

``stream.engine.stream_step`` is one fused jit per hop: featurise ->
embed -> ring -> encode.  The cell splits it at the existing
optimization-barrier seam into TWO jitted programs,

* ``featurise``: frontend_push + embed_frames + ring pushes -> the
  assembled [B, T, d] window (everything that depends on hop t's audio),
* ``encode``: window -> logits (the heavy encoder),

and exploits JAX's async dispatch: the host enqueues ``featurise`` for
hop t+1 immediately after enqueuing ``encode`` for hop t — never
blocking between them — so the feature front runs ahead of the encoder
by one hop (double buffering; chunks are staged with ``jax.device_put``
so the H2D copy also overlaps, and on backends that support it the state
buffers are donated).

Bit-identity comes for free: the pipelined path runs the SAME two
executables in the same per-lane order as the synchronous reference
(``step``), so their logits are equal by construction; and because the
split point is exactly the barrier ``stream_step`` already places before
its encoder, the split path reproduces the fused ``stream_step`` logits
bit-for-bit on every backend (tests/test_cell.py asserts both).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import ctx
from repro.models import kwt
from repro.stream import engine as stream_engine
from repro.stream import features
from repro.stream import ring


class HopPipeline:
    """The featurise/encode split of one engine's streaming plan.

    ``engine`` is a ``runtime.Engine`` or ``EngineHandle``; programs
    close over the plan's ``exec_cfg`` and take params as operands, so a
    hot-swap between hops needs no recompile.
    """

    def __init__(self, engine, fcfg: features.FrontendConfig,
                 keep_features: bool = False, donate: bool | None = None):
        eng = engine.engine if hasattr(engine, "engine") else engine
        cfg = eng.exec_cfg
        assert cfg.family == "kwt", "hop pipelining drives the KWT family"
        self._eng_ref = engine
        self.cfg, self.fcfg = cfg, fcfg
        self.keep_features = keep_features
        if donate is None:
            # CPU jax ignores donation with a warning; stay quiet there
            donate = jax.default_backend() != "cpu"

        def featurise(params, state, chunk):
            fe, frames = features.frontend_push(state["frontend"], chunk,
                                                fcfg)
            new = {"frontend": fe,
                   "embed": ring.ring_push(
                       state["embed"],
                       kwt.embed_frames(params, frames, cfg))}
            if "feat" in state:
                new["feat"] = ring.ring_push(state["feat"], frames)
            # the same seam stream_step fences: the encoder consumes only
            # the assembled window, never the hop-sized producers
            window = jax.lax.optimization_barrier(
                ctx.shard_activations(ring.ring_window(new["embed"])))
            return new, window

        self._feat = jax.jit(featurise,
                             donate_argnums=(1,) if donate else ())
        self._enc = jax.jit(lambda p, w: kwt.encode_window(p, w, cfg))

    def _params(self):
        ref = self._eng_ref
        return ref.live_params()

    def init_state(self, batch: int) -> dict:
        return stream_engine.init_stream_state(
            self.cfg, self.fcfg, batch, keep_features=self.keep_features)

    # -- synchronous reference --------------------------------------------

    def step(self, state, chunk):
        """One hop through the split programs: (state, chunk) ->
        (state, logits).  Logits are bit-identical to
        ``Engine.stream_step`` on the same chunk sequence."""
        p = self._params()
        state, window = self._feat(p, state, jnp.asarray(chunk))
        return state, self._enc(p, window)

    # -- pipelined loop ----------------------------------------------------

    def run(self, state, chunks):
        """Stream ``chunks`` with one-hop lookahead; yields
        ``(state_t, logits_t)`` per hop, dispatch order
        ``feat(0), enc(0), feat(1), enc(1), ...`` with NO host sync —
        while the device executes ``enc(t)``, the host is already
        staging chunk t+1 (``device_put``) and enqueuing ``feat(t+1)``.

        The yielded logits are live device arrays: a consumer that
        blocks on them immediately re-serialises the pipeline; batch a
        few hops (or poll) to keep the lookahead.
        """
        p = self._params()
        for chunk in chunks:
            staged = jax.device_put(jnp.asarray(chunk))
            state, window = self._feat(p, state, staged)
            yield state, self._enc(p, window)
