"""Checkpoint-watching hot-swap: new weights without dropping a lane.

The eval-side checkpoint loop (retrieve latest step / wait for a new
step / load for step) pointed at a directory where training (or QAT
export) publishes packed artifacts through ``checkpoint.manager`` —
atomic tmp+rename, so the watcher only ever sees complete steps.

A swap is a four-stage transaction (``hot_swap``):

1. **load**  — ``manager.restore`` reads the step's pytree (typically a
   packed QTensor tree straight from ``qat.export``) against a
   structure template and ``runtime.compile_model`` plans it under the
   SAME backend as the serving engine.
2. **warm**  — the probe batch runs through the new engine's entry
   points, forcing compile + first-touch off the serving path.
3. **verify** — the parity gate against a dequantise-first reference
   plan of the SAME artifact.  Non-executing integer-resident plans
   must be ``array_equal`` (the PR-5 bit-identity invariant, restated
   as a deploy gate).  Integer-EXECUTING plans quantise activations and
   clip residuals as part of their math, so bitwise equality to the
   float view is impossible by design; they gate on a max-abs bound
   (``_INT_EXEC_PROBE_TOL``, sized to the documented activation-quant
   envelope — a corrupted artifact lands orders of magnitude outside
   it).  Either way a broken plan fails CLOSED: the cell keeps serving
   the old engine.
4. **swap** — ``EngineHandle.swap`` installs the engine atomically
   under the handle's lock.  Lane state (rings, detector state, KV
   caches) lives outside the Engine and the exec config is unchanged by
   contract, so in-flight lanes continue on the same compiled serving
   programs with new params — no hop is dropped, no recompile.
"""

from __future__ import annotations

import time
from typing import Any, Optional

import jax
import numpy as np

from repro import runtime
from repro.checkpoint import manager
from repro.telemetry import log as _log


class SwapRejected(RuntimeError):
    """The parity gate refused the new artifact; the old engine serves on."""


# Max-abs probe-logit divergence an integer-EXECUTING plan may show
# against the dequantise-first float view of the same artifact: the
# eq-9 activation-quant + INT16-residual envelope (same family as the
# documented float-vs-lut logit tolerance).  Corruption (bit flips in
# the payload, wrong exponents) lands orders of magnitude outside.
_INT_EXEC_PROBE_TOL = 0.5


class CheckpointWatcher:
    """Polls a checkpoint directory for steps newer than the last seen.

    ``clock``/``sleep`` are injectable so waiting is unit-testable.
    """

    def __init__(self, ckpt_dir: str, *, poll_s: float = 0.5,
                 clock=time.monotonic, sleep=time.sleep):
        self.ckpt_dir = ckpt_dir
        self.poll_s = poll_s
        self._clock, self._sleep = clock, sleep
        self.last_step: Optional[int] = None

    def retrieve_latest_step(self) -> Optional[int]:
        """Newest COMPLETE step on disk (partial writes are invisible:
        manager.latest_step skips tmp dirs and manifest-less stragglers)."""
        return manager.latest_step(self.ckpt_dir)

    def poll(self) -> Optional[int]:
        """A step newer than the last seen, or None. Non-blocking."""
        step = self.retrieve_latest_step()
        if step is not None and (self.last_step is None
                                 or step > self.last_step):
            return step
        return None

    def wait_for_new_step(self, timeout_s: Optional[float] = None
                          ) -> Optional[int]:
        """Block (poll/sleep) until a new step appears; None on timeout."""
        t0 = self._clock()
        while True:
            step = self.poll()
            if step is not None:
                return step
            if timeout_s is not None and self._clock() - t0 >= timeout_s:
                return None
            self._sleep(self.poll_s)

    def load_for_step(self, step: int, like: Any) -> Any:
        """Read step's pytree against the ``like`` structure template and
        mark the step consumed."""
        tree = manager.restore(self.ckpt_dir, step, like)
        self.last_step = step
        return tree


def hot_swap(handle: "runtime.EngineHandle", params: Any, probe,
             *, metrics=None, strict: bool = True) -> "runtime.Engine":
    """Plan ``params`` under the handle's current backend, warm it, gate
    it on probe parity, and install it.  Returns the REPLACED engine.

    ``probe`` is a small representative input batch (mfcc for kwt,
    tokens for LMs).  Raises :class:`SwapRejected` (engine untouched)
    when the parity gate fails; propagates ``EngineHandle.swap``'s
    ``ValueError`` on exec-config/shape mismatch when ``strict``.
    """
    old = handle.engine
    t0 = time.perf_counter()
    new = runtime.compile_model(old.cfg, params, backend=old.backend_name)
    got = jax.block_until_ready(new.forward(probe))         # warm + compile
    if new.int_resident:
        # deploy gate: the packed plan must reproduce the
        # dequantise-first (non-executing) plan of the SAME artifact —
        # bitwise for resident plans, within the activation-quant
        # envelope for integer-executing ones (module docstring).
        ref = runtime.compile_model(old.cfg, params,
                                    backend=old.backend_name,
                                    integer_resident=False,
                                    integer_exec=False)
        want = jax.block_until_ready(ref.forward(probe))
        if new.int_exec:
            err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
            bad = not np.isfinite(err) or err > _INT_EXEC_PROBE_TOL
        else:
            bad = not np.array_equal(np.asarray(got), np.asarray(want))
        if bad:
            if metrics is not None:
                metrics.swap_failures.inc()
            raise SwapRejected(
                "probe logits of the packed plan diverge from the "
                "dequantise-first reference — artifact refused, old "
                "engine keeps serving")
    try:
        replaced = handle.swap(new, strict=strict)
    except ValueError:
        if metrics is not None:
            metrics.swap_failures.inc()
        raise
    dt_ms = 1e3 * (time.perf_counter() - t0)
    if metrics is not None:
        metrics.swaps.inc()
        metrics.swap_ms.observe(dt_ms)
        metrics.engine_generation.set(handle.generation)
    _log("hot_swap", generation=handle.generation, ms=dt_ms,
         backend=new.backend_name, resident=new.int_resident)
    return replaced


def poll_and_swap(handle, watcher: CheckpointWatcher, like: Any, probe,
                  *, metrics=None) -> bool:
    """One non-blocking watch tick for a serving loop: if a new complete
    step landed, load + hot-swap it.  Returns True when a swap happened.
    A rejected artifact is consumed (no retry storm) but not installed."""
    step = watcher.poll()
    if step is None:
        return False
    params = watcher.load_for_step(step, like)
    try:
        hot_swap(handle, params, probe, metrics=metrics)
    except SwapRejected:
        return False
    return True
