"""Mesh/sharding context: the model code's "where am I running".

Model code (transformer / encdec / moe) is written mesh-agnostically: it
calls the helpers below at every activation boundary and they resolve, at
trace time, to either a no-op (single device, no mesh — the KWT/CPU test
path) or a ``NamedSharding`` constraint on the ambient mesh when inside

    with mesh, ctx.mesh_context(dp_axes, seq_axis=...):
        ...

Axis conventions (launch/mesh.py, DESIGN.md §3):
  'pod', 'data'  — data-parallel / FSDP axes (``dp_axes``),
  'model'        — tensor-parallel axis; when ``seq_axis='model'`` the
                   activations additionally shard their SEQUENCE dim over
                   it between blocks (Megatron-SP), gathered just-in-time
                   by ``unshard_seq`` before attention/MLP.

Axis names not present on the ambient mesh are dropped from every
constraint, so the same model code runs on (data,), (data, model) and
(pod, data, model) meshes unchanged.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.interpreters.pxla import thread_resources
from jax.sharding import NamedSharding, PartitionSpec as P

TP = "model"   # tensor-parallel axis name (layers.TP; kept free of imports)


class _State(threading.local):
    active = False
    dp = None
    seq_axis = None


_STATE = _State()


@contextlib.contextmanager
def mesh_context(dp_axes, seq_axis=None):
    """Declare the data-parallel axes (and optional Megatron-SP sequence
    axis) that activation constraints shard over.  ``dp_axes`` may be
    None/() for replicated-batch cells (e.g. long-context batch 1).
    Contexts nest; the outer declaration is restored on exit."""
    prev = (_STATE.active, _STATE.dp, _STATE.seq_axis)
    _STATE.active = True
    _STATE.dp = tuple(dp_axes) if dp_axes else None
    _STATE.seq_axis = seq_axis
    try:
        yield
    finally:
        _STATE.active, _STATE.dp, _STATE.seq_axis = prev


def _mesh():
    return thread_resources.env.physical_mesh


def _mesh_active() -> bool:
    """True only under ``mesh_context`` AND a real (entered) device mesh."""
    return _STATE.active and not _mesh().empty


def dp_axes():
    """The data-parallel axes declared by the enclosing ``mesh_context``."""
    return _STATE.dp


def _present(axis, mesh):
    """Drop axis names the ambient mesh doesn't have."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept or None
    return axis if axis in mesh.axis_names else None


def _constrain(x, dims):
    mesh = _mesh()
    spec = P(*(_present(d, mesh) for d in dims))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_activations(x):
    """[B, S, D] activations: batch over the DP axes, sequence over the
    Megatron-SP axis when one was declared.  No-op off-mesh."""
    if not _mesh_active():
        return x
    return _constrain(x, (_STATE.dp, _STATE.seq_axis, None))


def unshard_seq(x):
    """Gather Megatron-SP sequence shards (attention/MLP need the full
    sequence); no-op unless a ``seq_axis`` was declared."""
    if not _mesh_active() or _STATE.seq_axis is None:
        return x
    return _constrain(x, (_STATE.dp, None, None))


def shard_logits(x):
    """[B, S, V] logits: batch over DP, vocab over TP (the lm_head is
    vocab-parallel, P(FSDP, TP) — keep its product sharded the same way
    instead of letting GSPMD replicate [B, S, V])."""
    if not _mesh_active():
        return x
    return _constrain(x, (_STATE.dp, None, TP))


def embed_lookup(embed, tokens):
    """Token-embedding gather.  On-mesh the result is pinned straight to
    the DP activation layout so GSPMD gathers from the d_model-sharded
    table in place rather than replicating the table through the take."""
    x = jnp.take(embed, tokens, axis=0)
    if _mesh_active():
        x = _constrain(x, (_STATE.dp, None, None))
    return x
