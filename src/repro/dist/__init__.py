"""Distribution layer between the model code and the mesh.

:mod:`repro.dist.ctx`       — sharding context: model code declares its
                              activation boundaries, the context resolves
                              them to NamedSharding constraints on-mesh
                              and to no-ops everywhere else.
:mod:`repro.dist.compress`  — int8 error-feedback gradient sync for the
                              slow (cross-pod) all-reduce.
"""

from repro.dist import compress, ctx  # noqa: F401
