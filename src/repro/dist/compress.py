"""int8-compressed gradient synchronisation with error feedback.

The paper's core bet — int8 payloads with carefully handled scales lose
almost nothing — applied to the distribution layer: the cross-pod
gradient all-reduce is the slowest wire in a multi-pod fleet (ICI within
a pod, DCN between pods), so compress exactly that hop to int8 and carry
the quantisation residual into the next step (error feedback: the bias
telescopes across steps, cf. sub-8-bit streaming-KWS training,
arXiv:2207.06920).

``compressed_grad_sync`` runs a ring all-reduce under ``shard_map``: each
of the N-1 hops moves the int8 payload plus its f32 scale one position
around the ring with ``ppermute`` — the compiled HLO moves ``s8`` arrays
over ``collective-permute`` — and every device accumulates the
dequantised shards in f32, then divides by the ring size (mean
semantics, matching a DP grad all-reduce).  Within-pod reduction stays
full-precision via the normal pjit partitioner; only the slow axis is
compressed.

Error feedback invariant (per leaf, in f32):

    c_t      = g_t + e_t            # residual-corrected gradient
    synced_t = mean_ring Q(c_t)     # what the optimizer sees
    e_{t+1}  = c_t - Q(c_t)         # what the wire dropped

so sum_t synced_t = sum_t g_t + e_0 - e_{T}: the accumulated estimate
drifts from the exact sum by at most one step's quantisation error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import quant


def reduce_axis(mesh) -> str:
    """The slow axis the compressed sync rings over: 'pod' when present
    (inter-pod DCN), else the outermost data axis."""
    for name in ("pod", "data"):
        if name in mesh.axis_names:
            return name
    return mesh.axis_names[0]


def quantize_leaf(g, per_channel: bool = False, *, bits: int = 8):
    """Symmetric ``bits``-wide payload: values in ±(2^(bits-1)-1) + f32
    scale(s), stored through the shared ``core.quant`` codec (int8 body at
    8 bits, nibble-packed uint8 — half the wire bytes — at ``bits<=4``).

    ``per_channel=True`` gives rank>=2 leaves one scale per leading-axis
    channel (rows of a [d_out, ...] gradient differ by orders of magnitude
    across fan-ins; a per-tensor scale crushes the small rows to zero).
    Rank<=1 leaves (biases, norm scales) always use the per-tensor scale —
    per-element scales would just re-encode the tensor.  The payload grows
    by one f32 per channel: negligible next to the int body.
    """
    hi = float(2 ** (bits - 1) - 1)
    g32 = g.astype(jnp.float32)
    if per_channel and g32.ndim >= 2:
        axes = tuple(range(1, g32.ndim))
        scale = jnp.maximum(jnp.max(jnp.abs(g32), axis=axes), 1e-30) / hi
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / hi
    q = jnp.clip(jnp.round(g32 / _expand(scale, g32.ndim)),
                 -hi, hi).astype(quant.storage_dtype(bits))
    return quant.pack_payload(q, bits), scale


def _expand(scale, ndim: int):
    """Broadcast a [d0] per-channel scale (or scalar) against a rank-ndim
    payload."""
    s = jnp.asarray(scale)
    return s.reshape(s.shape + (1,) * (ndim - s.ndim))


def dequantize_leaf(q, scale, *, bits: int = 8, shape=None):
    """Invert :func:`quantize_leaf`: unpack the wire payload through the
    shared codec (``shape`` is the logical leaf shape, required when the
    payload is nibble-packed) and re-apply the scale."""
    assert bits > 4 or shape is not None, \
        "nibble-packed payloads need the logical shape (q.shape is the " \
        "packed byte count)"
    vals = quant.unpack_payload(q, bits, q.shape if shape is None else shape)
    return vals.astype(jnp.float32) * _expand(scale, vals.ndim)


def init_error_state(grads):
    """Zeroed per-leaf f32 residuals, same tree structure as the grads."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _ring_mean(q, scale, axis, n, *, bits: int = 8, shape=None):
    """Gather-ring all-reduce of one quantised leaf: dequantise + f32
    accumulate locally at every hop (re-quantising partial sums each hop
    would compound error; moving the original shards does not).  The wire
    payload stays in its packed codec form across every ppermute hop."""
    acc = dequantize_leaf(q, scale, bits=bits, shape=shape)
    if n == 1:
        return acc
    perm = [(i, (i + 1) % n) for i in range(n)]
    for _ in range(n - 1):
        q = jax.lax.ppermute(q, axis, perm)
        scale = jax.lax.ppermute(scale, axis, perm)
        acc = acc + dequantize_leaf(q, scale, bits=bits, shape=shape)
    return acc / n


def compressed_grad_sync(grads, err, mesh, axis=None,
                         per_channel: bool = False, *, bits: int = 8):
    """Ring-mean ``grads`` over the mesh's slow axis with packed payloads.

    Returns ``(synced, new_err)``: the dequantised ring mean (same tree /
    dtypes as ``grads``) and the updated error-feedback state.  ``err``
    comes from :func:`init_error_state` on step 0 and is threaded through
    subsequent calls.  ``per_channel`` switches the payload to one scale
    per leading-axis channel (see :func:`quantize_leaf`); ``bits`` selects
    the wire width — 4 moves nibble-packed bytes (half the int8 wire)
    through the same ``core.quant`` codec the Engine stores weights with.
    The error-feedback conservation identity holds for every combination.
    """
    axis = axis or reduce_axis(mesh)
    n = mesh.shape[axis]
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(err)
    assert len(leaves) == len(err_leaves), \
        "error state does not match the gradient tree (init_error_state?)"

    def local(gs, es):
        synced, new_err = [], []
        for g, e in zip(gs, es):
            c = g.astype(jnp.float32) + e
            q, scale = quantize_leaf(c, per_channel=per_channel, bits=bits)
            new_err.append(c - dequantize_leaf(q, scale, bits=bits,
                                               shape=g.shape))
            synced.append(_ring_mean(q, scale, axis, n, bits=bits,
                                     shape=g.shape).astype(g.dtype))
        return tuple(synced), tuple(new_err)

    fn = jax.shard_map(local, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    synced, new_err = fn(tuple(leaves), tuple(err_leaves))
    return (jax.tree.unflatten(treedef, synced),
            jax.tree.unflatten(treedef, new_err))
