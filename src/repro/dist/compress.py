"""int8-compressed gradient synchronisation with error feedback.

The paper's core bet — int8 payloads with carefully handled scales lose
almost nothing — applied to the distribution layer: the cross-pod
gradient all-reduce is the slowest wire in a multi-pod fleet (ICI within
a pod, DCN between pods), so compress exactly that hop to int8 and carry
the quantisation residual into the next step (error feedback: the bias
telescopes across steps, cf. sub-8-bit streaming-KWS training,
arXiv:2207.06920).

``compressed_grad_sync`` runs a ring all-reduce under ``shard_map``: each
of the N-1 hops moves the int8 payload plus its f32 scale one position
around the ring with ``ppermute`` — the compiled HLO moves ``s8`` arrays
over ``collective-permute`` — and every device accumulates the
dequantised shards in f32, then divides by the ring size (mean
semantics, matching a DP grad all-reduce).  Within-pod reduction stays
full-precision via the normal pjit partitioner; only the slow axis is
compressed.

Error feedback invariant (per leaf, in f32):

    c_t      = g_t + e_t            # residual-corrected gradient
    synced_t = mean_ring Q(c_t)     # what the optimizer sees
    e_{t+1}  = c_t - Q(c_t)         # what the wire dropped

so sum_t synced_t = sum_t g_t + e_0 - e_{T}: the accumulated estimate
drifts from the exact sum by at most one step's quantisation error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def reduce_axis(mesh) -> str:
    """The slow axis the compressed sync rings over: 'pod' when present
    (inter-pod DCN), else the outermost data axis."""
    for name in ("pod", "data"):
        if name in mesh.axis_names:
            return name
    return mesh.axis_names[0]


def quantize_leaf(g, per_channel: bool = False):
    """Symmetric int8: values in [-127, 127] + f32 scale(s).

    ``per_channel=True`` gives rank>=2 leaves one scale per leading-axis
    channel (rows of a [d_out, ...] gradient differ by orders of magnitude
    across fan-ins; a per-tensor scale crushes the small rows to zero).
    Rank<=1 leaves (biases, norm scales) always use the per-tensor scale —
    per-element scales would just re-encode the tensor.  The payload grows
    by one f32 per channel: negligible next to the int8 body.
    """
    g32 = g.astype(jnp.float32)
    if per_channel and g32.ndim >= 2:
        axes = tuple(range(1, g32.ndim))
        scale = jnp.maximum(jnp.max(jnp.abs(g32), axis=axes), 1e-30) / 127.0
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / _expand(scale, g32.ndim)),
                 -127.0, 127.0).astype(jnp.int8)
    return q, scale


def _expand(scale, ndim: int):
    """Broadcast a [d0] per-channel scale (or scalar) against a rank-ndim
    payload."""
    s = jnp.asarray(scale)
    return s.reshape(s.shape + (1,) * (ndim - s.ndim))


def dequantize_leaf(q, scale):
    return q.astype(jnp.float32) * _expand(scale, q.ndim)


def init_error_state(grads):
    """Zeroed per-leaf f32 residuals, same tree structure as the grads."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _ring_mean(q, scale, axis, n):
    """Gather-ring all-reduce of one quantised leaf: dequantise + f32
    accumulate locally at every hop (re-quantising partial sums each hop
    would compound error; moving the original shards does not)."""
    acc = dequantize_leaf(q, scale)
    if n == 1:
        return acc
    perm = [(i, (i + 1) % n) for i in range(n)]
    for _ in range(n - 1):
        q = jax.lax.ppermute(q, axis, perm)
        scale = jax.lax.ppermute(scale, axis, perm)
        acc = acc + dequantize_leaf(q, scale)
    return acc / n


def compressed_grad_sync(grads, err, mesh, axis=None,
                         per_channel: bool = False):
    """Ring-mean ``grads`` over the mesh's slow axis with int8 payloads.

    Returns ``(synced, new_err)``: the dequantised ring mean (same tree /
    dtypes as ``grads``) and the updated error-feedback state.  ``err``
    comes from :func:`init_error_state` on step 0 and is threaded through
    subsequent calls.  ``per_channel`` switches the payload to one scale
    per leading-axis channel (see :func:`quantize_leaf`); the error-
    feedback conservation identity holds either way.
    """
    axis = axis or reduce_axis(mesh)
    n = mesh.shape[axis]
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(err)
    assert len(leaves) == len(err_leaves), \
        "error state does not match the gradient tree (init_error_state?)"

    def local(gs, es):
        synced, new_err = [], []
        for g, e in zip(gs, es):
            c = g.astype(jnp.float32) + e
            q, scale = quantize_leaf(c, per_channel=per_channel)
            new_err.append(c - dequantize_leaf(q, scale))
            synced.append(_ring_mean(q, scale, axis, n).astype(g.dtype))
        return tuple(synced), tuple(new_err)

    fn = jax.shard_map(local, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    synced, new_err = fn(tuple(leaves), tuple(err_leaves))
    return (jax.tree.unflatten(treedef, synced),
            jax.tree.unflatten(treedef, new_err))
