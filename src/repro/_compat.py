"""Forward-compatibility shims for the pinned jax toolchain.

The codebase is written against the jax >= 0.6 sharding spellings —
``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)`` — while the baked-in toolchain ships jax 0.4.37, where
shard_map still lives in ``jax.experimental`` (with ``check_rep`` instead
of ``check_vma``) and meshes have no axis types (every axis behaves as
``Auto``).  Importing :mod:`repro` installs the newer spellings; every
shim is hasattr-guarded so on a jax that already provides the API this
module does nothing.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install_axis_type():
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh():
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        del axis_types          # 0.4.x meshes are implicitly all-Auto
        return orig(axis_shapes, axis_names, **kw)

    jax.make_mesh = make_mesh


def _install_shard_map():
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)

    jax.shard_map = shard_map


def install():
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()


install()
