"""Flight recorder: a bounded black box for the serving cell.

A :class:`FlightRecorder` rides along a :class:`repro.cell.ServeCell`
keeping the last ``capacity`` hops in a ring — per-hop wall time,
optional per-stage span durations, and a snapshot of the admission /
swap counters at that hop.  Memory is bounded regardless of uptime
(same discipline as the metric ring reservoirs).

On every recorded hop it evaluates three anomaly triggers over the ring
window and, when one trips, writes a post-mortem JSON artifact and
re-arms only after the condition clears (one dump per incident, not one
per hop):

* **deadline-shed spike** — the admission controller's ``rejected``
  counter grew by ≥ ``shed_spike`` within the window (sheds and queue
  rejections both land there; a spike means lanes are missing their
  deadlines *now*);
* **SLO burn** — ≥ ``slo_burn_frac`` of the window's hops exceeded the
  ``cell_latency_budget_ms`` gauge (live-settable; 0 disables);
* **hot-swap probe failure** — ``swap_failures`` grew: a published
  checkpoint failed the bit-parity gate and was refused.

The dump is the debugging bundle an operator wants *after* the
incident: the hop ring (a trace), admission/swap counter deltas, the
full metric snapshot, and a **stage attribution** of the slow hops —
measured span means when the hops carried spans, otherwise the static
roofline-weighted stage split from
:func:`repro.perf.cost.stream_hop_cost` — naming the stage that owns
the regression (``"encode"``, ``"unpack"``, ...).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

TRIGGERS = ("shed_spike", "slo_burn", "swap_failure")


@dataclasses.dataclass(frozen=True)
class FlightConfig:
    """Ring size, trigger thresholds and dump location."""

    capacity: int = 256          # hops retained in the ring
    shed_spike: int = 8          # rejected-counter growth that trips
    slo_ms: float = 0.0          # seeds cell_latency_budget_ms (0 = unset)
    slo_burn_frac: float = 0.5   # fraction of window hops over budget
    min_hops: int = 16           # hops required before burn is evaluated
    dump_dir: str = "flight_dumps"


@dataclasses.dataclass
class HopRecord:
    """One ring slot: a hop's timing + the counter state right after it."""

    seq: int                     # monotone hop index (never wraps)
    t: float                     # recorder clock at observation
    duration_ms: float
    spans: Optional[dict]        # per-stage ms, when the hop was traced
    rejected: float
    swap_failures: float
    queue_depth: float
    occupancy: float

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["spans"] is None:
            del d["spans"]
        return d


class FlightRecorder:
    """Bounded hop ring + anomaly triggers + post-mortem dumps.

    ``stage_weights`` — ``{stage: fraction}`` summing to 1, or a
    zero-arg callable returning one (resolved lazily at first dump, so
    wiring the recorder costs nothing on the hot path) — is the static
    fallback attribution for hops recorded without spans.
    ``StreamLanes`` wires it from the cost model automatically.
    """

    def __init__(self, metrics, config: Optional[FlightConfig] = None,
                 stage_weights=None,
                 clock: Callable[[], float] = time.monotonic):
        self.metrics = metrics
        self.cfg = config or FlightConfig()
        self.stage_weights = stage_weights
        self._clock = clock
        self._ring: list = [None] * self.cfg.capacity
        self._seq = 0
        self._armed = {k: True for k in TRIGGERS}
        self.dumps: list = []            # paths written, in order
        if self.cfg.slo_ms > 0:
            metrics.latency_budget.set(self.cfg.slo_ms)

    # -- recording ---------------------------------------------------------

    def __len__(self) -> int:
        return min(self._seq, self.cfg.capacity)

    def window(self) -> list:
        """Ring contents in hop order (oldest first)."""
        n = len(self)
        start = self._seq - n
        return [self._ring[i % self.cfg.capacity]
                for i in range(start, self._seq)]

    def record_hop(self, duration_ms: float,
                   spans: Optional[dict] = None) -> Optional[str]:
        """Append one hop; returns a dump path if an anomaly tripped."""
        m = self.metrics
        rec = HopRecord(
            seq=self._seq, t=self._clock(),
            duration_ms=float(duration_ms),
            spans=dict(spans) if spans else None,
            rejected=m.rejected.value,
            swap_failures=m.swap_failures.value,
            queue_depth=m.queue_depth.value,
            occupancy=m.occupancy.value)
        self._ring[self._seq % self.cfg.capacity] = rec
        self._seq += 1
        return self.check()

    # -- triggers ----------------------------------------------------------

    def _trip_state(self) -> dict:
        win = self.window()
        if not win:
            return {k: False for k in TRIGGERS}
        first = win[0]
        m = self.metrics
        budget = m.latency_budget.value
        over = sum(r.duration_ms > budget for r in win) if budget > 0 else 0
        # counter deltas run oldest-snapshot -> LIVE value (not the last
        # snapshot), so check() sees growth between hops — e.g. a probe
        # failure during maybe_swap, before the next hop lands
        return {
            "shed_spike":
                m.rejected.value - first.rejected >= self.cfg.shed_spike,
            "slo_burn":
                budget > 0 and len(win) >= self.cfg.min_hops
                and over >= self.cfg.slo_burn_frac * len(win),
            "swap_failure":
                m.swap_failures.value - first.swap_failures > 0,
        }

    def check(self) -> Optional[str]:
        """Evaluate triggers against the current window; dump on a fresh
        trip (armed -> tripped edge), re-arm once the condition clears.
        Call between hops too (e.g. after a swap attempt) — it reads
        counters, it does not consume a ring slot."""
        state = self._trip_state()
        path = None
        for kind in TRIGGERS:
            if state[kind] and self._armed[kind]:
                self._armed[kind] = False
                path = self.dump(kind)
            elif not state[kind]:
                self._armed[kind] = True
        return path

    # -- attribution & dumping ---------------------------------------------

    def _weights(self) -> Optional[dict]:
        w = self.stage_weights
        if callable(w):
            w = self.stage_weights = w()
        return w

    def attribution(self) -> dict:
        """Name the stage that owns the window's slow hops.

        Slow = over budget when one is set, else above 2× the window
        median.  Attribution prefers measured spans (mean per stage over
        the slow hops); hops recorded without spans fall back to the
        static cost-model stage weights scaled by the mean slow
        duration.  ``slowest_stage`` is the argmax either way.
        """
        win = self.window()
        if not win:
            return {"slow_hops": 0, "stage_ms": {}, "slowest_stage": None}
        budget = self.metrics.latency_budget.value
        if budget > 0:
            slow = [r for r in win if r.duration_ms > budget]
        else:
            med = sorted(r.duration_ms for r in win)[len(win) // 2]
            slow = [r for r in win if r.duration_ms > 2 * med]
        if not slow:
            slow = sorted(win, key=lambda r: -r.duration_ms)[:1]
        mean_ms = sum(r.duration_ms for r in slow) / len(slow)

        spanned = [r for r in slow if r.spans]
        if spanned:
            stage_ms: dict = {}
            for r in spanned:
                for k, v in r.spans.items():
                    stage_ms[k] = stage_ms.get(k, 0.0) + v
            stage_ms = {k: round(v / len(spanned), 4)
                        for k, v in stage_ms.items()}
            method = "measured-spans"
        else:
            w = self._weights() or {"encode": 1.0}
            stage_ms = {k: round(f * mean_ms, 4) for k, f in w.items()}
            method = "cost-model-weights"
        slowest = max(stage_ms, key=stage_ms.get)
        return {"slow_hops": len(slow),
                "slow_mean_ms": round(mean_ms, 4),
                "method": method, "stage_ms": stage_ms,
                "slowest_stage": slowest}

    def dump(self, reason: str) -> str:
        """Write the post-mortem artifact; returns its path."""
        from repro.perf import ledger   # lazy: telemetry must not need perf

        m = self.metrics
        win = self.window()
        os.makedirs(self.cfg.dump_dir, exist_ok=True)
        path = os.path.join(self.cfg.dump_dir,
                            f"flight_{len(self.dumps):03d}_{reason}.json")
        artifact = {
            "reason": reason,
            "provenance": ledger.provenance(),
            "config": dataclasses.asdict(self.cfg),
            "window_hops": len(win),
            "attribution": self.attribution(),
            "admission": {
                "admitted": m.admitted.value,
                "degraded": m.degraded.value,
                "rejected": m.rejected.value,
                "rejected_in_window":
                    win[-1].rejected - win[0].rejected if win else 0,
                "queue_depth": m.queue_depth.value,
            },
            "hotswap": {
                "swaps": m.swaps.value,
                "swap_failures": m.swap_failures.value,
                "engine_generation": m.engine_generation.value,
            },
            "latency_budget_ms": m.latency_budget.value,
            "hop_latency": m.hop_ms.summary(),
            "trace": [r.to_dict() for r in win],
        }
        with open(path, "w") as f:
            json.dump(artifact, f, indent=2)
        self.dumps.append(path)
        return path
