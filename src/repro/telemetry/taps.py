"""In-graph quantisation-health taps (the ``compile_model(taps=True)`` aux).

A *tap* is a scalar health statistic computed inside the jitted forward
from a tensor the plan already materialises — the numbers that tell you
whether the paper's fixed-point pipeline is running inside its numeric
envelope:

* ``int8_sat_frac``   — fraction of activation values that would clip at
  the int8 edge under the plan's eq-9 input grid (``x * 2^e_in`` vs
  ±127).  Rising saturation means the Table V input exponent is too hot
  for this data.
* ``q24_headroom_bits`` — integer bits to spare before ``|x|`` reaches
  the Q8.24 representable edge (128).  Negative: ``ALU_TO_FIXED`` is
  saturating.
* ``lut_oob_frac``    — fraction of lanes hitting a LUT domain clip:
  softmax ``max(x) - x_i > 10`` (the eq-11 table edge, where the paper's
  pipeline silently leaks ``e^{-10}``), GELU inputs outside
  [-1.857, 1.595] (exact-tail region — benign, but drift here tracks
  activation-scale drift).
* ``q24_sum_headroom_bits`` — int32 bits to spare in the fixed softmax's
  numerator accumulator (the §VI overflow guard the pre-shift protects).

Collection protocol: model code calls :func:`tap_activation` /
:func:`tap_softmax` / :func:`tap_gelu` unconditionally — with no active
collector these return immediately (one module-global ``None`` check at
*trace* time, nothing in the compiled program), so the untapped plan's
jaxpr is byte-identical with or without this module imported.  The
Engine's taps program wraps its forward trace in :func:`collecting` and
returns :func:`pack` of what accumulated; :func:`scope` prefixes names
(``block0/softmax``) so per-layer stats stay distinguishable.

The stat math intentionally *re-derives* cheap elementwise stages
(max-subtract, table index) from the tapped tensor rather than plumbing
intermediates out of ``core.approx``'s STE-wrapped primals: a tap inside
a ``custom_vjp`` primal would leak its trace's tracers into the aux
output.  The formulas mirror ``approx.softmax_lut`` / ``gelu_lut``
line-for-line.
"""

from __future__ import annotations

import contextlib

import jax.numpy as jnp

from repro.core import fixedpoint as fxp
from repro.core import lut as lutlib

_ACTIVE: list | None = None
_SCOPE: list[str] = []

_Q24_EPS = 2.0 ** -24


def active() -> bool:
    return _ACTIVE is not None


@contextlib.contextmanager
def collecting():
    """Route taps emitted while tracing into a fresh collector list."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, []
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def scope(name: str):
    """Prefix taps emitted inside with ``<name>/`` (per-layer naming)."""
    if _ACTIVE is None:
        yield
        return
    _SCOPE.append(name)
    try:
        yield
    finally:
        _SCOPE.pop()


def _emit(site: str, stats: dict):
    _ACTIVE.append(("/".join(_SCOPE + [site]), stats))


def _headroom_bits(maxabs, edge_bits: int):
    """Bits to spare before ``maxabs`` reaches ``2^edge_bits``."""
    return (float(edge_bits)
            - jnp.ceil(jnp.log2(jnp.maximum(maxabs, _Q24_EPS))))


def tap_activation(site: str, x, cfg):
    """int8-grid saturation + Q8.24 headroom of one activation tensor."""
    if _ACTIVE is None:
        return
    q = getattr(cfg, "quant", None)
    e_in = q.input_exponent if q is not None else 5
    absx = jnp.abs(x.astype(jnp.float32))
    hi = 2.0 ** 7 - 1                       # int8 clip edge on the input grid
    sat = jnp.mean((absx * (2.0 ** e_in) >= hi).astype(jnp.float32))
    _emit(site, {"int8_sat_frac": sat,
                 "q24_headroom_bits": _headroom_bits(jnp.max(absx), 7)})


def tap_softmax(scores, mask=None, *, fixed: bool = False):
    """LUT exp out-of-domain fraction (+ Q8.24 accumulator headroom when
    the fixed pipeline runs) for one softmax's score tensor."""
    if _ACTIVE is None:
        return
    s = scores.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    sm = s if mask is None else jnp.where(mask, s, neg)
    z = jnp.max(sm, axis=-1, keepdims=True) - s       # >= 0 on valid lanes
    oob = (z > lutlib.EXP_RANGE)
    if mask is not None:
        valid = jnp.broadcast_to(mask, z.shape)
        n_valid = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
        frac = jnp.sum(jnp.logical_and(oob, valid).astype(jnp.float32)) \
            / n_valid
    else:
        frac = jnp.mean(oob.astype(jnp.float32))
    stats = {"lut_oob_frac": frac}
    if fixed:
        # mirror of approx.masked_softmax's lut_fixed accumulator: numerators
        # from the eq-11 ROM, pre-shifted so the int32 row sum cannot wrap.
        bank = lutlib.make_lut_bank()
        zc = jnp.clip(z, 0.0, lutlib.EXP_RANGE)
        num_q = jnp.take(jnp.asarray(bank.exp_q24),
                         lutlib.exp_index_from_q24(fxp.to_fixed(zc)))
        if mask is not None:
            num_q = jnp.where(jnp.broadcast_to(mask, num_q.shape), num_q, 0)
        import numpy as np
        k_len = s.shape[-1]
        pre = max(0, int(np.ceil(np.log2(max(k_len, 1)))) - 6)
        if pre > 0:
            num_q = (num_q + (1 << (pre - 1))) >> pre
        s_q = jnp.sum(num_q, axis=-1)
        max_sq = jnp.maximum(jnp.max(s_q).astype(jnp.float32), 1.0)
        stats["q24_sum_headroom_bits"] = \
            31.0 - jnp.ceil(jnp.log2(max_sq))
    _emit("softmax", stats)


def tap_gelu(x):
    """Fraction of GELU inputs outside the 32-entry table's [LO, HI]."""
    if _ACTIVE is None:
        return
    xf = x.astype(jnp.float32)
    out = jnp.logical_or(xf > lutlib.GELU_HI, xf < lutlib.GELU_LO)
    _emit("gelu", {"lut_oob_frac": jnp.mean(out.astype(jnp.float32))})


def pack(collected: list) -> dict:
    """Collector list -> ``{name: {stat: scalar}}`` with unique names
    (repeat sites outside any scope get ``#<k>`` suffixes)."""
    out: dict = {}
    for name, stats in collected:
        key, k = name, 1
        while key in out:
            key = f"{name}#{k}"
            k += 1
        out[key] = stats
    return out
