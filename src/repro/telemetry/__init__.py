"""repro.telemetry — span tracing, serve metrics, quantisation-health taps.

Three layers (see README §repro.telemetry):

* :mod:`repro.telemetry.trace`   — host-side nested spans -> Chrome/Perfetto
  trace-event JSON; free when disabled.
* :mod:`repro.telemetry.metrics` — counters / gauges / ring-reservoir
  histograms with Prometheus-text + JSON export and the shared
  :func:`latency_summary` schema; :func:`log` structured log lines.
* :mod:`repro.telemetry.taps`    — in-graph quantisation-health statistics
  collected by the Engine's opt-in ``compile_model(..., taps=True)`` aux
  program (int8 saturation, LUT out-of-domain fractions, Q8.24 headroom).
* :mod:`repro.telemetry.flight`  — a bounded flight recorder for the
  serving cell: last-N-hops ring + anomaly-triggered post-mortem dumps
  with cost-model stage attribution (see README §repro.perf).

:func:`annotate` names a stage *inside* a jitted program (a
``jax.named_scope`` pass-through): metadata-only, shows up in jaxprs /
XLA profiles, never changes numerics.
"""

from jax import named_scope as annotate

from repro.telemetry import taps
from repro.telemetry.cell import CellMetrics, make_cell_metrics
from repro.telemetry.flight import FlightConfig, FlightRecorder, HopRecord
from repro.telemetry.check import (
    TelemetryFormatError,
    validate_chrome_trace,
    validate_prometheus,
)
from repro.telemetry.metrics import (
    Counter,
    Gauge,
    Histogram,
    Registry,
    default_registry,
    latency_summary,
    log,
)
from repro.telemetry.trace import (
    NOOP_SPAN,
    Tracer,
    active_tracer,
    disable,
    enable,
    span,
    span_coverage,
    tracing,
)

__all__ = [
    "NOOP_SPAN",
    "CellMetrics",
    "Counter",
    "FlightConfig",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "HopRecord",
    "Registry",
    "TelemetryFormatError",
    "Tracer",
    "active_tracer",
    "annotate",
    "default_registry",
    "disable",
    "enable",
    "latency_summary",
    "log",
    "make_cell_metrics",
    "span",
    "span_coverage",
    "taps",
    "tracing",
    "validate_chrome_trace",
    "validate_prometheus",
]
