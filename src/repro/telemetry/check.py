"""Validators for telemetry artifacts + the ``python -m repro.telemetry`` CLI.

Checks a Chrome trace-event JSON file (and, when present, the sibling
``.prom`` / ``.metrics.json`` exports the serve loops write next to it)
against the format contracts:

* Chrome trace-event: top-level object with a ``traceEvents`` list;
  every event has ``name``/``ph``/``ts``/``pid``/``tid``; ``ph: "X"``
  (complete) events additionally carry a non-negative ``dur``.
  (The subset of the trace-event spec that chrome://tracing and
  Perfetto require to load the file.)
* Prometheus text exposition: every non-comment line is
  ``name{labels} value``; every ``# TYPE`` is a known metric type; no
  sample appears before its TYPE line.

Used by tests/test_telemetry.py and the CI telemetry smoke step:

    python -m repro.launch.stream_serve --hops 20 --telemetry-out trace.json
    python -m repro.telemetry trace.json
"""

from __future__ import annotations

import json
import os
import re
import sys

_EVENT_REQUIRED = ("name", "ph", "ts", "pid", "tid")
_PROM_TYPES = {"counter", "gauge", "summary", "histogram", "untyped"}
_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})?\s+-?[0-9.eE+\-]+(\s+\d+)?$")
_PROM_META = re.compile(r"^# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]*( .*)?$")


class TelemetryFormatError(ValueError):
    pass


def validate_chrome_trace(path_or_obj) -> int:
    """Validate a Chrome trace-event JSON file (or loaded object).

    Returns the number of events; raises :class:`TelemetryFormatError`
    with the first violation otherwise.
    """
    if isinstance(path_or_obj, (str, os.PathLike)):
        with open(path_or_obj) as f:
            obj = json.load(f)
    else:
        obj = path_or_obj
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise TelemetryFormatError(
            "trace must be a JSON object with a 'traceEvents' list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise TelemetryFormatError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise TelemetryFormatError(f"event {i} is not an object")
        for key in _EVENT_REQUIRED:
            if key not in ev:
                raise TelemetryFormatError(f"event {i} missing {key!r}")
        if not isinstance(ev["name"], str) or not isinstance(ev["ph"], str):
            raise TelemetryFormatError(f"event {i}: name/ph must be strings")
        if not isinstance(ev["ts"], (int, float)):
            raise TelemetryFormatError(f"event {i}: ts must be a number")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise TelemetryFormatError(
                    f"event {i}: complete (ph=X) event needs dur >= 0")
    return len(events)


def validate_prometheus(text: str) -> int:
    """Validate Prometheus text exposition; returns the sample count."""
    typed: set[str] = set()
    samples = 0
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            if not _PROM_META.match(line):
                raise TelemetryFormatError(f"line {ln}: bad comment {line!r}")
            parts = line.split()
            if parts[1] == "TYPE":
                if parts[3] not in _PROM_TYPES:
                    raise TelemetryFormatError(
                        f"line {ln}: unknown metric type {parts[3]!r}")
                typed.add(parts[2])
            continue
        if not _PROM_SAMPLE.match(line):
            raise TelemetryFormatError(f"line {ln}: bad sample {line!r}")
        name = re.split(r"[{\s]", line, 1)[0]
        base = re.sub(r"_(sum|count|bucket|total)$", "", name)
        if name not in typed and base not in typed \
                and name.rstrip("_total") not in typed:
            raise TelemetryFormatError(
                f"line {ln}: sample {name!r} has no preceding # TYPE")
        samples += 1
    return samples


def check_artifacts(trace_path: str, *, require_metrics: bool = False) -> dict:
    """Validate a trace file and (when present) its sibling metric exports
    (``<base>.prom``, ``<base>.metrics.json``).  Returns a summary dict."""
    n_events = validate_chrome_trace(trace_path)
    out = {"trace": trace_path, "events": n_events}
    base = os.path.splitext(trace_path)[0]
    prom = base + ".prom"
    if os.path.exists(prom):
        with open(prom) as f:
            out["prom_samples"] = validate_prometheus(f.read())
    elif require_metrics:
        raise TelemetryFormatError(f"missing Prometheus export {prom}")
    mjson = base + ".metrics.json"
    if os.path.exists(mjson):
        with open(mjson) as f:
            out["metrics"] = len(json.load(f))
    elif require_metrics:
        raise TelemetryFormatError(f"missing metrics JSON {mjson}")
    return out


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    require = "--require-metrics" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if not paths:
        print("usage: python -m repro.telemetry [--require-metrics] "
              "trace.json [...]", file=sys.stderr)
        return 2
    for path in paths:
        try:
            summary = check_artifacts(path, require_metrics=require)
        except (TelemetryFormatError, OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            return 1
        print("OK " + " ".join(f"{k}={v}" for k, v in summary.items()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
