"""The serving cell's metric vocabulary (``cell_*``).

One place defines every instrument a :class:`repro.cell.ServeCell`
exports, so dashboards, tests and the CI soak read a stable schema
instead of grepping call sites.  All instruments live on an ordinary
:class:`~repro.telemetry.metrics.Registry` (get-or-create semantics —
building the bundle twice on one registry returns the same instruments)
and export through the registry's usual Prometheus/JSON paths.

Counters end in ``_total``; admission decisions carry a ``decision``
label so one metric name covers admitted / degraded / rejected lanes.
"""

from __future__ import annotations

import dataclasses

from repro.telemetry.metrics import Counter, Gauge, Histogram, Registry


@dataclasses.dataclass
class CellMetrics:
    """Every instrument of one serving cell (see module docstring)."""

    # lane lifecycle (streams and LM request slots alike)
    joins: Counter            # cell_lane_joins_total
    evictions: Counter        # cell_lane_evictions_total
    occupancy: Gauge          # cell_lane_occupancy (active / slots)

    # admission control (cell.admission)
    admitted: Counter         # cell_admission_total{decision="admit"}
    degraded: Counter         # cell_admission_total{decision="degrade"}
    rejected: Counter         # cell_admission_total{decision="reject"}
    queue_depth: Gauge        # cell_queue_depth

    # hop/token flow
    hops: Counter             # cell_hops_total (per-lane hops ingested)
    dropped_hops: Counter     # cell_dropped_hops_total (MUST stay 0)
    tokens: Counter           # cell_tokens_total (LM tokens decoded)
    prefill_tokens: Counter   # cell_prefill_tokens_total (joined prompts)
    hop_ms: Histogram         # cell_hop_latency_ms
    decode_ms: Histogram      # cell_decode_latency_ms
    prefill_ms: Histogram     # cell_prefill_latency_ms
    latency_budget: Gauge     # cell_latency_budget_ms (SLO; 0 = unset)

    # checkpoint hot-swap (cell.hotswap)
    swaps: Counter            # cell_swaps_total
    swap_failures: Counter    # cell_swap_failures_total (parity gate)
    swap_ms: Histogram        # cell_swap_latency_ms (load+warm+verify+swap)
    engine_generation: Gauge  # cell_engine_generation


def make_cell_metrics(registry: Registry) -> CellMetrics:
    """Register (or fetch) the full ``cell_*`` instrument set."""
    adm = "admission decisions for offered lanes"
    return CellMetrics(
        joins=registry.counter("cell_lane_joins_total",
                               "lanes joined into the batch in flight"),
        evictions=registry.counter("cell_lane_evictions_total",
                                   "lanes evicted (EOS / stream end)"),
        occupancy=registry.gauge("cell_lane_occupancy",
                                 "active lanes / batch slots"),
        admitted=registry.counter("cell_admission_total", adm,
                                  labels={"decision": "admit"}),
        degraded=registry.counter("cell_admission_total", adm,
                                  labels={"decision": "degrade"}),
        rejected=registry.counter("cell_admission_total", adm,
                                  labels={"decision": "reject"}),
        queue_depth=registry.gauge("cell_queue_depth",
                                   "lanes waiting for a slot"),
        hops=registry.counter("cell_hops_total",
                              "per-lane stream hops ingested"),
        dropped_hops=registry.counter(
            "cell_dropped_hops_total",
            "hops lost to churn/swap (the soak asserts 0)"),
        tokens=registry.counter("cell_tokens_total", "LM tokens decoded"),
        prefill_tokens=registry.counter("cell_prefill_tokens_total",
                                        "prompt tokens prefilled at join"),
        hop_ms=registry.histogram("cell_hop_latency_ms",
                                  "stream hop wall time", unit="ms"),
        decode_ms=registry.histogram("cell_decode_latency_ms",
                                     "LM decode step wall time", unit="ms"),
        prefill_ms=registry.histogram("cell_prefill_latency_ms",
                                      "LM join prefill wall time",
                                      unit="ms"),
        latency_budget=registry.gauge(
            "cell_latency_budget_ms",
            "per-hop latency SLO; the flight recorder burns against "
            "this (0 = no budget set)"),
        swaps=registry.counter("cell_swaps_total",
                               "checkpoint hot-swaps completed"),
        swap_failures=registry.counter(
            "cell_swap_failures_total",
            "hot-swaps rejected by the probe parity gate"),
        swap_ms=registry.histogram(
            "cell_swap_latency_ms",
            "hot-swap load+warm+verify+install wall time", unit="ms"),
        engine_generation=registry.gauge(
            "cell_engine_generation",
            "EngineHandle generation (bumps once per swap)"),
    )
