"""``python -m repro.telemetry [--require-metrics] trace.json [...]``

Artifact-validation CLI (same as ``repro.telemetry.check.main``, but the
package entry point avoids runpy's found-in-sys.modules warning that
``python -m repro.telemetry.check`` triggers — the package __init__
imports the check module).
"""

import sys

from repro.telemetry.check import main

if __name__ == "__main__":
    sys.exit(main())
