"""Counters, gauges and ring-reservoir histograms + Prometheus/JSON export.

The serving-side half of ``repro.telemetry`` (the host analogue of
paxml's ``base_metrics``): one :class:`Registry` of named metrics shared
by the serve loops (per-hop latency, lane occupancy, queue depth, refill
rate, per-stream RTF, detector event counts) and the benchmark harnesses
(``benchmarks/run.py --backend-sweep``, ``benchmarks/stream_bench.py``).

:func:`latency_summary` is the ONE latency-row schema: both BENCH_*.json
rows and live ``Histogram.summary()`` exports use its field names
(``n`` / ``mean_<unit>`` / ``p50_<unit>`` / ``p95_<unit>`` /
``p99_<unit>``), so a dashboard reading serve metrics and a script
reading bench JSON parse the same keys.

Histograms keep a fixed-capacity ring reservoir (latest N observations)
— bounded memory under millions of hops, with quantiles over the recent
window, which is what a serving cell wants anyway.
"""

from __future__ import annotations

import json
import re
import threading
import time

import numpy as np

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _fmt_labels(labels: dict | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{v}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def latency_summary(samples, *, unit: str = "us", count: int | None = None,
                    total: float | None = None) -> dict:
    """The shared latency-row schema (bench JSON rows == serve metrics).

    ``samples`` is any sequence of per-call latencies in ``unit``;
    ``count``/``total`` override n / sum when the samples are a reservoir
    of a longer-running stream.

    Empty input is a real serving state (a cold cell exporting metrics
    before first traffic): the summary reports ``n=0`` with zeroed
    stats rather than raising from numpy quantiles over an empty ring.
    """
    a = np.asarray(list(samples), np.float64)
    if a.size == 0:
        return {"n": int(count or 0), f"mean_{unit}": 0.0,
                f"p50_{unit}": 0.0, f"p95_{unit}": 0.0, f"p99_{unit}": 0.0}
    p50, p95, p99 = np.percentile(a, [50, 95, 99])
    return {"n": int(count if count is not None else a.size),
            f"mean_{unit}": round(float(np.mean(a)), 4),
            f"p50_{unit}": round(float(p50), 4),
            f"p95_{unit}": round(float(p95), 4),
            f"p99_{unit}": round(float(p99), 4)}


class Counter:
    """Monotonic counter (Prometheus ``counter``)."""

    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name, help="", labels=None):
        self.name, self.help, self.labels = name, help, labels
        self.value = 0.0

    def inc(self, n=1.0):
        self.value += n

    def to_prometheus(self) -> str:
        n = _prom_name(self.name)
        return (f"# HELP {n} {self.help}\n# TYPE {n} counter\n"
                f"{n}{_fmt_labels(self.labels)} {self.value:g}\n")

    def to_json(self) -> dict:
        return {"type": "counter", "value": self.value,
                **({"labels": self.labels} if self.labels else {})}


class Gauge:
    """Point-in-time value (Prometheus ``gauge``)."""

    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name, help="", labels=None):
        self.name, self.help, self.labels = name, help, labels
        self.value = 0.0

    def set(self, v):
        self.value = float(v)

    def to_prometheus(self) -> str:
        n = _prom_name(self.name)
        return (f"# HELP {n} {self.help}\n# TYPE {n} gauge\n"
                f"{n}{_fmt_labels(self.labels)} {self.value:g}\n")

    def to_json(self) -> dict:
        return {"type": "gauge", "value": self.value,
                **({"labels": self.labels} if self.labels else {})}


class Histogram:
    """Ring-reservoir histogram: quantiles over the latest ``capacity``
    observations, exported as a Prometheus ``summary`` (p50/p95/p99).

    ``unit`` names the measurement unit in the JSON summary keys
    (``mean_ms`` etc — the :func:`latency_summary` schema).
    """

    __slots__ = ("name", "help", "labels", "unit", "_buf", "_n", "_sum",
                 "_lock")

    def __init__(self, name, help="", labels=None, capacity=1024, unit="ms"):
        self.name, self.help, self.labels = name, help, labels
        self.unit = unit
        self._buf = np.empty((capacity,), np.float64)
        self._n = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v):
        v = float(v)
        with self._lock:
            self._buf[self._n % self._buf.size] = v
            self._n += 1
            self._sum += v

    @property
    def count(self) -> int:
        return self._n

    def values(self) -> np.ndarray:
        """The retained reservoir (latest ``capacity`` observations)."""
        with self._lock:
            return self._buf[:min(self._n, self._buf.size)].copy()

    def quantile(self, q: float) -> float:
        v = self.values()
        return float(np.percentile(v, 100.0 * q)) if v.size else 0.0

    def summary(self) -> dict:
        v = self.values()
        return latency_summary(v, unit=self.unit, count=self._n)

    def to_prometheus(self) -> str:
        n = _prom_name(self.name)
        base = "" if not self.labels else _fmt_labels(self.labels)[1:-1]
        lines = [f"# HELP {n} {self.help}", f"# TYPE {n} summary"]
        for q in (0.5, 0.95, 0.99):
            labels = f'{{{base + "," if base else ""}quantile="{q:g}"}}'
            lines.append(f"{n}{labels} {self.quantile(q):g}")
        suffix = _fmt_labels(self.labels)
        lines.append(f"{n}_sum{suffix} {self._sum:g}")
        lines.append(f"{n}_count{suffix} {self._n}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> dict:
        return {"type": "histogram", "summary": self.summary(),
                **({"labels": self.labels} if self.labels else {})}


class Registry:
    """Named metrics with one Prometheus-text + one JSON exporter.

    Get-or-create semantics: asking twice for the same (name, labels)
    returns the same instance, so call sites don't thread metric handles
    around.
    """

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, help, labels, **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, help, labels, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name, help="", labels=None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=None, capacity=1024,
                  unit="ms") -> Histogram:
        return self._get(Histogram, name, help, labels,
                         capacity=capacity, unit=unit)

    def metrics(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    def to_prometheus(self) -> str:
        return "".join(m.to_prometheus() for m in self.metrics())

    def to_json(self) -> dict:
        out = {}
        for m in self.metrics():
            entry = m.to_json()
            if m.name in out:       # same name, different labels
                prev = out[m.name]
                stack = prev if isinstance(prev, list) else [prev]
                stack.append(entry)
                entry = stack
            out[m.name] = entry
        return out

    def save(self, prefix: str) -> tuple[str, str]:
        """Write ``<prefix>.prom`` (Prometheus text exposition) and
        ``<prefix>.metrics.json``; returns both paths."""
        prom, js = prefix + ".prom", prefix + ".metrics.json"
        with open(prom, "w") as f:
            f.write(self.to_prometheus())
        with open(js, "w") as f:
            json.dump(self.to_json(), f, indent=2)
        return prom, js


_DEFAULT = Registry()


def default_registry() -> Registry:
    return _DEFAULT


def log(event: str, **fields) -> str:
    """One structured log line: ``event=<name> ts=<unix> k=v ...``.

    The serve loops' replacement for ad-hoc prints — machine-parseable
    key=value pairs, floats at 4 significant digits, strings with spaces
    quoted.  Returns the line (tests parse it) after printing.
    """
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        if isinstance(v, str) and (" " in v or "=" in v):
            return json.dumps(v)
        return str(v)

    parts = [f"event={event}", f"ts={time.time():.3f}"]
    parts += [f"{k}={fmt(v)}" for k, v in fields.items()]
    line = " ".join(parts)
    print(line, flush=True)
    return line
