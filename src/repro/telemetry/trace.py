"""Span tracing: nested wall-clock spans -> Chrome/Perfetto trace JSON.

The paper's 5x story started from per-op clock-cycle attribution (Figs
3-5: GELU/SoftMax dominate the 26M-cycle inference); this module is the
repo's analogue for Engine plans.  A :class:`Tracer` records nested
``span("unpack")`` / ``span("encode")`` / ... context managers as Chrome
trace-event *complete* events (``ph: "X"``, microsecond ``ts``/``dur``)
that load directly into ``chrome://tracing`` / Perfetto, plus an optional
``jax.profiler`` annotation pass-through so the same span names appear in
XLA device profiles.

Design constraints (tests/test_telemetry.py):

* **Disabled fast path is free.**  ``telemetry.span(name)`` with no
  active tracer returns one shared no-op context manager — no object,
  tuple or dict is allocated per call, so instrumented hot paths
  (``Engine.forward``) cost one global read + ``None`` check when
  tracing is off.
* **Spans measure device work, not dispatch.**  Callers fence jitted
  results with ``jax.block_until_ready`` *inside* the span when (and
  only when) a tracer is active; async dispatch is preserved otherwise.
* **Nesting is explicit.**  Each event records its parent span name in
  ``args["parent"]``, which is what :func:`span_coverage` uses to check
  that named child stages account for a parent's wall time.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time


class _NoopSpan:
    """Shared do-nothing context manager (the tracing-disabled fast path)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb):
        return False


NOOP_SPAN = _NoopSpan()


class _Span:
    """One live span of an enabled tracer (created per ``Tracer.span``)."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_annotation")

    def __init__(self, tracer, name, args):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0
        self._annotation = None

    def __enter__(self):
        tr = self._tracer
        tr._stack().append(self.name)
        if tr.profiler:
            import jax.profiler
            self._annotation = jax.profiler.TraceAnnotation(self.name)
            self._annotation.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        tr = self._tracer
        stack = tr._stack()
        stack.pop()
        args = dict(self.args) if self.args else {}
        if stack:
            args["parent"] = stack[-1]
        tr._record(self.name, self._t0, t1, args)
        return False


class Tracer:
    """Collects spans as Chrome trace-event JSON (``ph: "X"`` events).

    ``profiler=True`` additionally wraps every span in a
    ``jax.profiler.TraceAnnotation`` so the names show up in XLA device
    traces captured by ``jax.profiler.trace``.
    """

    def __init__(self, *, profiler: bool = False):
        self.events: list[dict] = []
        self.profiler = profiler
        self._epoch = time.perf_counter_ns()
        self._local = threading.local()
        self._lock = threading.Lock()

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, name, t0_ns, t1_ns, args):
        ev = {"name": name, "cat": "repro", "ph": "X",
              "ts": (t0_ns - self._epoch) / 1e3,        # microseconds
              "dur": (t1_ns - t0_ns) / 1e3,
              "pid": os.getpid(), "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def span(self, name: str, args: dict | None = None) -> _Span:
        """Context manager timing one named (nested) stage."""
        return _Span(self, name, args)

    def instant(self, name: str, args: dict | None = None):
        """A zero-duration marker event (``ph: "i"``)."""
        ev = {"name": name, "cat": "repro", "ph": "i", "s": "t",
              "ts": (time.perf_counter_ns() - self._epoch) / 1e3,
              "pid": os.getpid(), "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    # -- inspection / export ----------------------------------------------

    def durations_us(self, name: str) -> list[float]:
        """All recorded durations (microseconds) of spans called ``name``."""
        return [e["dur"] for e in self.events
                if e.get("ph") == "X" and e["name"] == name]

    def to_chrome(self) -> dict:
        """The Chrome trace-event file format (JSON object flavour)."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def span_coverage(tracer_or_events, parent: str,
                  children: tuple | None = None) -> float:
    """Fraction of ``parent`` span wall time accounted for by its direct
    named children (optionally restricted to ``children`` names).

    The acceptance gate for the telemetry layer: named stages must
    explain >= 90% of measured ``Engine.forward`` time per backend —
    anything less means a stage is missing a span.
    """
    events = tracer_or_events.events \
        if isinstance(tracer_or_events, Tracer) else tracer_or_events
    parent_us = sum(e["dur"] for e in events
                    if e.get("ph") == "X" and e["name"] == parent)
    if parent_us <= 0:
        return 0.0
    child_us = sum(
        e["dur"] for e in events
        if e.get("ph") == "X"
        and e.get("args", {}).get("parent") == parent
        and (children is None or e["name"] in children))
    return child_us / parent_us


# ---------------------------------------------------------------------------
# Module-level active tracer (what the instrumented call sites consult)
# ---------------------------------------------------------------------------

_ACTIVE: Tracer | None = None


def enable(tracer: Tracer | None = None, *, profiler: bool = False) -> Tracer:
    """Install ``tracer`` (or a fresh one) as the active tracer."""
    global _ACTIVE
    _ACTIVE = tracer if tracer is not None else Tracer(profiler=profiler)
    return _ACTIVE


def disable() -> Tracer | None:
    """Deactivate tracing; returns the tracer that was active (if any)."""
    global _ACTIVE
    tr, _ACTIVE = _ACTIVE, None
    return tr


def active_tracer() -> Tracer | None:
    return _ACTIVE


def span(name: str, args: dict | None = None):
    """Span under the active tracer, or the shared no-op when disabled.

    The disabled path allocates nothing: it returns the module-level
    ``NOOP_SPAN`` singleton (fixed-arity ``__exit__``, ``__slots__``),
    which is what keeps un-traced ``Engine.forward`` calls free.
    """
    tr = _ACTIVE
    if tr is None:
        return NOOP_SPAN
    return tr.span(name, args)


@contextlib.contextmanager
def tracing(*, profiler: bool = False):
    """Scoped enable: ``with tracing() as tr: ... tr.save(path)``."""
    tr = enable(profiler=profiler)
    try:
        yield tr
    finally:
        disable()
