"""repro.stream — always-on streaming KWS (paper §III deployed shape).

Turns the offline KWT + quantised LUT stack into a streaming detector:

  features.py   streaming log-mel/MFCC frontend (framing -> FFT -> mel
                filterbank -> DCT) with a hop-at-a-time incremental API
  ring.py       externalized ring-buffer state pytrees (the kws_streaming
                external-state idiom): pure (state, frames) -> state
  engine.py     incremental KWT inference, bit-identical to offline
                ``models.kwt.forward`` on the same window (float + LUT)
  detector.py   posterior smoothing + hysteresis/refractory triggering

State lives in pytrees, never in Python objects, so serving slots are
checkpointable and shardable like any other model state.
"""
