"""Keyword event triggering: posterior smoothing + hysteresis + refractory.

Converts the per-hop posteriors of ``engine.stream_step`` into discrete
keyword events.  Standard streaming-KWS posterior handling: a moving
average over the last ``smooth_hops`` hops suppresses single-hop spikes;
a two-threshold hysteresis (fire at ``on_threshold``, release below
``off_threshold``) stops one keyword utterance firing once per hop; a
refractory period bounds the event rate even across releases.

Everything is a pure pytree function, batched over lanes — the detector
state rides in the same jitted server step as the engine state.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.stream import ring


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    keyword_class: int = 1        # index of the "dog" class (paper §III)
    smooth_hops: int = 5          # posterior moving-average window
    on_threshold: float = 0.75    # fire when smoothed posterior crosses up
    off_threshold: float = 0.5    # release (re-arm) when it falls below
    refractory_hops: int = 20     # min hops between fires, even if released


def detector_init(dcfg: DetectorConfig, batch: int) -> dict:
    return {"hist": ring.ring_init(batch, dcfg.smooth_hops, ()),
            "active": jnp.zeros((batch,), bool),
            "cooldown": jnp.zeros((batch,), jnp.int32),
            "warm_hops": jnp.zeros((batch,), jnp.int32),
            "hop": jnp.zeros((), jnp.int32)}


def detector_step(state: dict, probs: jnp.ndarray, dcfg: DetectorConfig,
                  warm=None) -> tuple[dict, dict]:
    """One hop: ``probs`` [B, n_classes] -> (state, events).

    ``events = {"fired": [B] bool, "score": [B] smoothed posterior,
    "hop": scalar hop index}``.  ``warm`` gates lanes whose engine window
    is still filling (their logits describe zero-padded audio).

    Hysteresis semantics: a fire sets ``active``; the lane cannot fire
    again until the smoothed posterior *releases* below ``off_threshold``
    AND the refractory countdown has expired.
    """
    hist = ring.ring_push(state["hist"],
                          probs[:, dcfg.keyword_class][:, None])
    # mean over the hops actually seen (count < smooth_hops during warm-up;
    # unwritten slots hold zeros and are excluded by dividing by count)
    smoothed = jnp.sum(hist["buf"], axis=1) \
        / jnp.maximum(hist["count"].astype(jnp.float32), 1.0)
    # a lane may only fire after smooth_hops consecutive *warm* hops: the
    # history ring also collects posteriors of still-padded windows, and
    # those must age out before the average is trusted (otherwise a model
    # that scores silence keyword-like fires at the warm-up boundary)
    is_warm = jnp.ones_like(state["active"]) if warm is None else warm
    warm_hops = jnp.where(is_warm, state["warm_hops"] + 1, 0)
    ready = warm_hops >= dcfg.smooth_hops
    cooldown = jnp.maximum(state["cooldown"] - 1, 0)
    fired = (ready & ~state["active"] & (cooldown == 0)
             & (smoothed >= dcfg.on_threshold))
    active = jnp.where(fired, True,
                       state["active"] & (smoothed > dcfg.off_threshold))
    cooldown = jnp.where(fired, dcfg.refractory_hops, cooldown)
    hop = state["hop"] + 1
    new = {"hist": hist, "active": active, "cooldown": cooldown,
           "warm_hops": warm_hops, "hop": hop}
    return new, {"fired": fired, "score": smoothed, "hop": hop}


def detector_reset_lane(state: dict, lane) -> dict:
    """Re-arm lane(s) on evict/join: the recycled-lane contract.

    A detector lane carries memory — the posterior history ring, the
    hysteresis latch (``active``), the refractory countdown and the
    warm-up count.  ALL of it belongs to the stream, not the slot: a
    server that recycles a lane without this reset hands the next stream
    the previous one's state, so a stream joining right after a fire
    inherits a live refractory countdown (its own early keyword is
    silently suppressed) or a latched hysteresis (never fires at all) —
    tests/test_cell.py demonstrates both.  ``cell.StreamLanes.join``
    calls this unconditionally; ``lane`` may be an int or an index array
    (one batched reset for a multi-lane join).
    """
    return {"hist": ring.ring_reset_lane(state["hist"], lane),
            "active": state["active"].at[lane].set(False),
            "cooldown": state["cooldown"].at[lane].set(0),
            "warm_hops": state["warm_hops"].at[lane].set(0),
            "hop": state["hop"]}


def event_time_s(hop, fcfg) -> float:
    """Hop index -> stream timestamp in seconds (end of the hop)."""
    return float(hop) * fcfg.hop_len / fcfg.sample_rate
