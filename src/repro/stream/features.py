"""Streaming log-mel/MFCC frontend: framing -> FFT -> mel -> DCT.

The real-audio data path the offline repo lacked (paper §III trains on
MFCC features of 1 s GSC clips; ``data.pipeline.keyword_batch`` only
synthesises the *features*).  This module maps raw waveforms to the
``[B, n_mfcc, T]`` tensors ``models.kwt.forward`` consumes, in two
equivalent forms:

  * :func:`mfcc` — whole-utterance (offline) featurisation;
  * :func:`frontend_init` / :func:`frontend_push` — hop-at-a-time
    incremental featurisation with externalized state, the streaming
    form: ``(state, chunk) -> (state, frames)``.

Equivalence contract (tested bit-exactly in tests/test_stream.py): a
stream is treated as left-padded with ``frame_len - hop_len`` zeros, so
hop ``t`` (both paths) featurises samples
``[t*hop - (frame_len - hop), t*hop + hop)`` of the unpadded signal and
every ``hop_len`` new samples yield exactly one new frame.  Both paths
run the identical per-frame math (Hann window, ``|rfft|^2``, mel matmul,
``log``, orthonormal DCT-II), so streaming frames are bit-identical to
offline frames.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Frontend hyperparameters (defaults: 16 kHz, 25 ms frames, 10 ms hop,
    16 MFCC coefficients — the paper's F=16 feature dim)."""

    sample_rate: int = 16_000
    frame_len: int = 400          # 25 ms analysis window
    hop_len: int = 160            # 10 ms hop -> one frame per hop
    n_fft: int = 512
    n_mels: int = 40
    n_mfcc: int = 16              # == cfg.input_dim[0] for KWT
    fmin: float = 20.0
    fmax: float = 7_600.0
    log_floor: float = 1e-6

    @property
    def context_len(self) -> int:
        """Samples of left context carried between hops."""
        return self.frame_len - self.hop_len

    def receptive_field(self, t_frames: int) -> int:
        """Samples covered by a ``t_frames`` model window:
        frame_len + (t_frames - 1) * hop_len."""
        return self.frame_len + (t_frames - 1) * self.hop_len


def _hz_to_mel(f):
    return 2595.0 * np.log10(1.0 + np.asarray(f) / 700.0)


def _mel_to_hz(m):
    return 700.0 * (10.0 ** (np.asarray(m) / 2595.0) - 1.0)


def mel_filterbank(fcfg: FrontendConfig) -> np.ndarray:
    """Triangular mel filterbank [n_fft//2 + 1, n_mels] (HTK-style mel)."""
    n_bins = fcfg.n_fft // 2 + 1
    freqs = np.linspace(0.0, fcfg.sample_rate / 2.0, n_bins)
    mels = np.linspace(_hz_to_mel(fcfg.fmin), _hz_to_mel(fcfg.fmax),
                       fcfg.n_mels + 2)
    edges = _mel_to_hz(mels)                       # [n_mels + 2]
    fb = np.zeros((n_bins, fcfg.n_mels), np.float32)
    for m in range(fcfg.n_mels):
        lo, c, hi = edges[m], edges[m + 1], edges[m + 2]
        up = (freqs - lo) / max(c - lo, 1e-9)
        down = (hi - freqs) / max(hi - c, 1e-9)
        fb[:, m] = np.maximum(0.0, np.minimum(up, down))
    return fb


def dct_matrix(n_mels: int, n_mfcc: int) -> np.ndarray:
    """Orthonormal DCT-II [n_mels, n_mfcc]."""
    n = np.arange(n_mels)[:, None]
    k = np.arange(n_mfcc)[None, :]
    d = np.cos(np.pi * (2 * n + 1) * k / (2 * n_mels)) \
        * np.sqrt(2.0 / n_mels)
    d[:, 0] *= np.sqrt(0.5)
    return d.astype(np.float32)


def _frame_features(frames: jnp.ndarray, fcfg: FrontendConfig) -> jnp.ndarray:
    """Per-frame MFCC math on framed audio [B, t, frame_len] -> [B, t, n_mfcc].

    The single shared realisation of the frame pipeline: both the offline
    and the streaming path call exactly this function, which is what makes
    them bit-identical (every op here is row-wise in t).
    """
    win = jnp.asarray(np.hanning(fcfg.frame_len).astype(np.float32))
    x = frames.astype(jnp.float32) * win
    spec = jnp.fft.rfft(x, n=fcfg.n_fft, axis=-1)
    power = jnp.square(spec.real) + jnp.square(spec.imag)
    mel = power @ jnp.asarray(mel_filterbank(fcfg))
    logmel = jnp.log(jnp.maximum(mel, fcfg.log_floor))
    return logmel @ jnp.asarray(dct_matrix(fcfg.n_mels, fcfg.n_mfcc))


def _frame(audio: jnp.ndarray, fcfg: FrontendConfig) -> jnp.ndarray:
    """[B, ctx + k*hop] samples -> [B, k, frame_len] overlapping frames."""
    n = audio.shape[-1] - fcfg.context_len
    k = n // fcfg.hop_len
    idx = (np.arange(k)[:, None] * fcfg.hop_len
           + np.arange(fcfg.frame_len)[None, :])
    return audio[..., idx]


def mfcc(audio: jnp.ndarray, fcfg: FrontendConfig) -> jnp.ndarray:
    """Offline featurisation: audio [B, n] (n % hop == 0) -> [B, n_mfcc, T]
    with T = n // hop_len (left zero-padded by ``context_len`` samples)."""
    if audio.ndim == 1:
        audio = audio[None]
    assert audio.shape[-1] % fcfg.hop_len == 0, \
        "offline mfcc expects whole hops (pad the tail)"
    pad = jnp.zeros(audio.shape[:-1] + (fcfg.context_len,), audio.dtype)
    feats = _frame_features(_frame(jnp.concatenate([pad, audio], -1), fcfg),
                            fcfg)
    return jnp.swapaxes(feats, -1, -2)             # [B, n_mfcc, T]


# ---------------------------------------------------------------------------
# Streaming form: externalized state, (state, chunk) -> (state, frames)
# ---------------------------------------------------------------------------

def frontend_init(fcfg: FrontendConfig, batch: int) -> dict:
    """Fresh frontend state: the ``context_len``-sample tail of the stream
    (zeros == the offline left padding)."""
    return {"tail": jnp.zeros((batch, fcfg.context_len), jnp.float32)}


def frontend_push(state: dict, chunk: jnp.ndarray,
                  fcfg: FrontendConfig) -> tuple[dict, jnp.ndarray]:
    """Featurise ``chunk`` [B, k*hop_len] -> (new_state, frames [B, k, n_mfcc]).

    Pure function of (state, chunk): feeding the same stream in any chunking
    (all sizes that are whole hops) yields the same frames bit-for-bit.
    """
    assert chunk.ndim == 2 and chunk.shape[-1] % fcfg.hop_len == 0, \
        "chunks must be [B, k * hop_len]"
    buf = jnp.concatenate([state["tail"], chunk.astype(jnp.float32)], -1)
    frames = _frame_features(_frame(buf, fcfg), fcfg)
    return {"tail": buf[:, -fcfg.context_len:]}, frames
