"""Externalized ring-buffer state pytrees for streaming inference.

The kws_streaming external-state idiom, functionally: state is a plain
pytree of arrays — ``{"buf", "pos", "count"}`` — and every operation is a
pure function ``(state, frames) -> state`` / ``state -> window``, so a
serving slot's streaming state lives in checkpoints, donated jit buffers
and sharded device memory exactly like model params, never in Python
objects.

Layout: ``buf`` is ``[B, length, ...]`` with a *shared* scalar write
cursor ``pos`` (all lanes of a batched server advance hop-synchronously)
and a *per-lane* ``count`` [B] so freshly refilled slots can warm up
mid-stream (see ``launch/stream_serve.py``).  ``window`` reads the last
``length`` entries out in chronological order, oldest first.
"""

from __future__ import annotations

import jax.numpy as jnp


def ring_init(batch: int, length: int, feat_shape: tuple,
              dtype=jnp.float32) -> dict:
    """Zeroed ring holding ``length`` feature vectors per lane."""
    return {"buf": jnp.zeros((batch, length) + tuple(feat_shape), dtype),
            "pos": jnp.zeros((), jnp.int32),
            "count": jnp.zeros((batch,), jnp.int32)}


def ring_len(state: dict) -> int:
    return state["buf"].shape[1]


def ring_push(state: dict, frames: jnp.ndarray) -> dict:
    """Write ``frames`` [B, k, ...] at pos..pos+k-1 (mod length), advance.

    k is a static shape; pos is traced — the scatter wraps around the end
    of the buffer without data movement (true ring, not a shift buffer).
    """
    length = ring_len(state)
    k = frames.shape[1]
    assert k <= length, \
        f"push of {k} frames overruns the {length}-frame ring: the modulo " \
        "scatter would write duplicate indices (unspecified winner)"
    idx = (state["pos"] + jnp.arange(k)) % length
    return {"buf": state["buf"].at[:, idx].set(frames.astype(state["buf"].dtype)),
            "pos": (state["pos"] + k) % length,
            "count": jnp.minimum(state["count"] + k, length)}


def ring_window(state: dict) -> jnp.ndarray:
    """Chronological read-out [B, length, ...], oldest entry first.

    After a push, ``pos`` points at the oldest live entry (the next to be
    overwritten), so the window is the gather ``(pos + arange(L)) % L``.
    Lanes with ``count < length`` still contain init zeros in their oldest
    slots — gate on :func:`ring_warm` before trusting the window.
    """
    length = ring_len(state)
    idx = (state["pos"] + jnp.arange(length)) % length
    return jnp.take(state["buf"], idx, axis=1)


def ring_warm(state: dict) -> jnp.ndarray:
    """[B] bool: lane has seen a full window of real frames."""
    return state["count"] >= ring_len(state)


def ring_reset_lane(state: dict, lane) -> dict:
    """Zero one lane's history (slot refill in the batched server): the
    shared cursor keeps advancing; the lane re-warms via its own count."""
    return {"buf": state["buf"].at[lane].set(0),
            "pos": state["pos"],
            "count": state["count"].at[lane].set(0)}
