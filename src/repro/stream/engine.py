"""Incremental KWT inference over a hop-synchronous stream.

Per hop, only the newly arrived time-patches are embedded
(``models.kwt.embed_frames`` on [B, k, F]) and pushed into a ring of
cached patch embeddings; the encoder (``models.kwt.encode_window``) then
runs on the assembled [B, T, d] window.  Because the patch embedding
contracts over F independently per frame, the assembled window is
bit-identical to embedding the whole window at once — so streaming
logits are **bit-identical** to the offline ``jax.jit(models.kwt.forward)``
program on the same audio window (both sides compiled, as production
always is), in the float path and in every LUT/Pallas path: callers pass
a ``repro.runtime`` Engine's ``exec_cfg``/``params`` (or drive
``Engine.stream_step`` directly), so PTQ and mode selection happen once
at plan time before anything reaches this module.

State is one pytree (frontend tail + feature ring + embedding ring):
``stream_step`` is pure ``(params, state, chunk) -> (state, logits)`` —
the deployment contract for millions of checkpointable serving slots.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import ctx
from repro.models import kwt
from repro.stream import features
from repro.stream import ring
from repro.telemetry import annotate


def window_frames(cfg) -> int:
    """The model's receptive field in frames (T of input_dim [F, T])."""
    return cfg.input_dim[1]


def init_stream_state(cfg, fcfg: features.FrontendConfig, batch: int,
                      keep_features: bool = True) -> dict:
    """Fresh streaming state for ``batch`` hop-synchronous streams.

    The embed ring caches per-frame patch embeddings so each hop re-embeds
    only its new frames.  ``keep_features`` additionally keeps the raw MFCC
    history ring (offline-parity oracles, calibration taps); production
    servers pass False to drop that scatter + state from the hot path.
    """
    t, f = window_frames(cfg), cfg.input_dim[0]
    state = {"frontend": features.frontend_init(fcfg, batch),
             "embed": ring.ring_init(batch, t, (cfg.d_model,),
                                     jnp.dtype(cfg.dtype))}
    if keep_features:
        state["feat"] = ring.ring_init(batch, t, (f,), jnp.float32)
    return state


def stream_step(params, state: dict, chunk: jnp.ndarray, cfg,
                fcfg: features.FrontendConfig) -> tuple[dict, jnp.ndarray]:
    """Advance every stream by ``chunk`` [B, k*hop_len] samples.

    Returns ``(state, logits [B, n_classes])``.  Logits are valid once
    :func:`warm` is True for the lane (a full receptive field of real
    frames); before that the window still contains init zeros.
    """
    # named_scope stages (telemetry.annotate) are metadata-only: they name
    # the featurise/embed/encode regions in jaxprs and XLA profiles without
    # touching numerics or fusion decisions.
    with annotate("featurise"):
        fe, frames = features.frontend_push(state["frontend"], chunk, fcfg)
    new = {"frontend": fe}
    if "feat" in state:
        new["feat"] = ring.ring_push(state["feat"], frames)
    with annotate("embed"):
        emb = ring.ring_push(state["embed"],
                             kwt.embed_frames(params, frames, cfg))
    new["embed"] = emb
    # barrier: the encoder must see only the assembled [B, T, d] window, not
    # the hop-sized producers — otherwise XLA fuses frontend/ring ops into
    # the encoder and its rounding becomes a function of the chunk size k,
    # breaking bit-identity with the offline jit(kwt.forward) program.
    # shard_activations pins the packed multi-stream batch to the DP axes
    # under launch/stream_serve.py's mesh (exact no-op off-mesh).
    window = jax.lax.optimization_barrier(
        ctx.shard_activations(ring.ring_window(emb)))
    with annotate("encode"):
        logits = kwt.encode_window(params, window, cfg)
    return new, logits


def stream_step_frames(params, state: dict, frames: jnp.ndarray,
                       cfg) -> tuple[dict, jnp.ndarray]:
    """Advance every stream by ``frames`` [B, k, F] pre-featurised MFCC
    frames — the edge-featurised ingest path.

    The paper's deployment computes MFCCs on the device next to the
    microphone; a serving cell aggregating such streams receives feature
    frames (F coefficients/hop), not raw audio.  This entrypoint is
    ``stream_step`` minus the frontend: feeding it the frames that
    ``features.frontend_push`` produces for a chunk yields bit-identical
    logits and state to ``stream_step`` on that chunk (the frontend tail
    is carried, untouched, so the two paths stay interchangeable per
    lane; tests/test_cell.py pins this through ``cell.StreamLanes``).
    """
    new = {"frontend": state["frontend"]}
    if "feat" in state:
        new["feat"] = ring.ring_push(state["feat"], frames)
    with annotate("embed"):
        emb = ring.ring_push(state["embed"],
                             kwt.embed_frames(params, frames, cfg))
    new["embed"] = emb
    # same barrier rationale as stream_step: the encoder sees only the
    # assembled window, keeping its rounding independent of k.
    window = jax.lax.optimization_barrier(
        ctx.shard_activations(ring.ring_window(emb)))
    with annotate("encode"):
        logits = kwt.encode_window(params, window, cfg)
    return new, logits


def warm(state: dict) -> jnp.ndarray:
    """[B] bool: lane's window is fully populated with real frames."""
    return ring.ring_warm(state["embed"])


def window_mfcc(state: dict) -> jnp.ndarray:
    """The current feature window as an offline batch [B, F, T] — feeding
    this to ``models.kwt.forward`` reproduces ``stream_step``'s logits
    bit-for-bit (the equivalence tests' oracle)."""
    return jnp.swapaxes(ring.ring_window(state["feat"]), 1, 2)


def reset_lane(state: dict, lane) -> dict:
    """Zero one stream's history (server slot refill): frontend tail,
    feature/embedding rings and warm-up count all restart for that lane."""
    new = {"frontend": {"tail": state["frontend"]["tail"].at[lane].set(0.0)},
           "embed": ring.ring_reset_lane(state["embed"], lane)}
    if "feat" in state:
        new["feat"] = ring.ring_reset_lane(state["feat"], lane)
    return new


def posteriors(logits: jnp.ndarray) -> jnp.ndarray:
    """Per-hop class posteriors for the detector (f32 softmax on the f32
    logits both quantised and float paths emit)."""
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
