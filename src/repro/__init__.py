"""KWT-Tiny reproduction, grown toward a production-scale jax system."""

from repro import _compat  # noqa: F401  (jax API shims; must import first)
